//! Shard health watchdog: a monitor thread that classifies every fleet
//! shard as Healthy / Degraded / Stalled from cheap liveness probes.
//!
//! Fleet workers heartbeat (an atomic timestamp) on every loop
//! iteration — idle workers wake at least every `IDLE_POLL` (10ms), so
//! a heartbeat older than [`WatchdogConfig::stall_after`] means the
//! worker is *stuck*: wedged inside the model's forward call or dead
//! without having marked itself exited.  Classification, in priority
//! order:
//!
//! 1. worker thread exited (factory failure, panic unwound) ->
//!    [`ShardState::Stalled`] `"worker exited"`;
//! 2. heartbeat older than `stall_after` -> `Stalled` (the probe that
//!    catches a hung `run_batch`);
//! 3. oldest queued request older than `max_queue_age` ->
//!    [`ShardState::Degraded`] (work is moving, but not fast enough);
//! 4. windowed SLO miss-rate above `max_slo_miss_rate` -> `Degraded`
//!    (model-level signal, applied to its shards);
//! 5. otherwise `Healthy` — including a shard whose worker has not
//!    started yet (model factories can take seconds; startup is not a
//!    failure).
//!
//! The watchdog never takes a queue's formation lock for longer than a
//! depth/front probe and runs off the serving path entirely.  Its
//! report feeds `/healthz` (HTTP 503 when any shard is Stalled) and the
//! `health` block of the obs snapshot (`shard_up` etc. in
//! `/metrics`) — see `docs/OBSERVABILITY.md`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::export::ShardHealthAttr;

/// Watchdog thresholds.
#[derive(Clone, Copy, Debug)]
pub struct WatchdogConfig {
    /// probe interval (also bounds how fast `/healthz` reacts)
    pub period: Duration,
    /// heartbeat age beyond which a started, non-exited shard is Stalled
    pub stall_after: Duration,
    /// oldest-queued-request age beyond which a live shard is Degraded
    pub max_queue_age: Duration,
    /// windowed (10s) SLO miss-rate beyond which a model's live shards
    /// are Degraded; only evaluated for models with an SLO configured
    pub max_slo_miss_rate: f64,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig {
            period: Duration::from_millis(100),
            stall_after: Duration::from_millis(500),
            max_queue_age: Duration::from_millis(250),
            max_slo_miss_rate: 0.5,
        }
    }
}

/// One shard's classified state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardState {
    Healthy,
    /// serving, but a soft threshold is breached
    Degraded { reason: String },
    /// not making progress — flips `/healthz` to 503
    Stalled { reason: String },
}

impl ShardState {
    /// Lowercase state name — the `state` string in
    /// [`ShardHealthAttr`] and the `shard_health_state` metric label.
    pub fn name(&self) -> &'static str {
        match self {
            ShardState::Healthy => "healthy",
            ShardState::Degraded { .. } => "degraded",
            ShardState::Stalled { .. } => "stalled",
        }
    }

    pub fn reason(&self) -> &str {
        match self {
            ShardState::Healthy => "",
            ShardState::Degraded { reason } | ShardState::Stalled { reason } => {
                reason
            }
        }
    }

    /// Counts toward `/healthz` 200 (everything except Stalled).
    pub fn is_up(&self) -> bool {
        !matches!(self, ShardState::Stalled { .. })
    }
}

/// Raw observations the fleet takes for one shard — classification
/// input, kept separate so [`classify`] is pure and unit-testable.
#[derive(Clone, Debug, Default)]
pub struct ShardProbe {
    /// worker thread has entered its loop (factory finished)
    pub started: bool,
    /// worker thread has returned (factory failure or shutdown drain)
    pub exited: bool,
    /// time since the worker's last loop iteration (None: none yet)
    pub heartbeat_age: Option<Duration>,
    pub queue_depth: u64,
    /// age of the oldest queued request (None: queue empty)
    pub oldest_queue_age: Option<Duration>,
}

/// Classify one shard (see the module docs for the priority order).
/// `slo_miss_rate` is the model's windowed miss-rate, `None` when the
/// model has no SLO configured.
pub fn classify(
    p: &ShardProbe,
    slo_miss_rate: Option<f64>,
    cfg: &WatchdogConfig,
) -> ShardState {
    if p.exited {
        return ShardState::Stalled { reason: "worker exited".to_string() };
    }
    if !p.started {
        return ShardState::Healthy; // startup grace: factory still building
    }
    if let Some(age) = p.heartbeat_age {
        if age > cfg.stall_after {
            return ShardState::Stalled {
                reason: format!("no heartbeat for {:.2}s", age.as_secs_f64()),
            };
        }
    }
    if let Some(age) = p.oldest_queue_age {
        if age > cfg.max_queue_age {
            return ShardState::Degraded {
                reason: format!(
                    "oldest queued request waiting {:.0}ms",
                    age.as_secs_f64() * 1e3
                ),
            };
        }
    }
    if let Some(rate) = slo_miss_rate {
        if rate > cfg.max_slo_miss_rate {
            return ShardState::Degraded {
                reason: format!("windowed SLO miss-rate {:.0}%", rate * 100.0),
            };
        }
    }
    ShardState::Healthy
}

/// One shard's classified health plus the probe facts worth exporting.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardHealth {
    pub shard: usize,
    pub state: ShardState,
    /// seconds since the worker's last heartbeat (0 before the first)
    pub heartbeat_age_s: f64,
    pub queue_depth: u64,
}

impl ShardHealth {
    /// Lower into the schema-stable obs representation.
    pub fn to_attr(&self) -> ShardHealthAttr {
        ShardHealthAttr {
            shard: self.shard,
            state: self.state.name().to_string(),
            reason: self.state.reason().to_string(),
            last_batch_age_s: self.heartbeat_age_s,
            queue_depth: self.queue_depth,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ModelHealth {
    pub model: String,
    pub shards: Vec<ShardHealth>,
}

/// The watchdog's published board: every model's shard states as of
/// the last probe.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HealthReport {
    pub models: Vec<ModelHealth>,
}

impl HealthReport {
    /// No shard anywhere is Stalled (the `/healthz` 200 condition).
    pub fn all_up(&self) -> bool {
        self.models
            .iter()
            .all(|m| m.shards.iter().all(|s| s.state.is_up()))
    }

    /// Every shard is fully Healthy (no Degraded either).
    pub fn all_healthy(&self) -> bool {
        self.models
            .iter()
            .all(|m| m.shards.iter().all(|s| s.state == ShardState::Healthy))
    }

    /// One model's shard states lowered for the obs snapshot.
    pub fn attrs_for(&self, model: &str) -> Vec<ShardHealthAttr> {
        self.models
            .iter()
            .find(|m| m.model == model)
            .map(|m| m.shards.iter().map(ShardHealth::to_attr).collect())
            .unwrap_or_default()
    }
}

/// The monitor thread.  `probe` runs once per period and returns the
/// fresh report; the fleet supplies a closure with access to its shard
/// internals (heartbeats, queue depths), keeping this type free of any
/// fleet dependency.
pub struct Watchdog {
    stop: Arc<AtomicBool>,
    board: Arc<Mutex<HealthReport>>,
    handle: Option<JoinHandle<()>>,
}

impl Watchdog {
    pub fn spawn<F>(cfg: WatchdogConfig, probe: F) -> Watchdog
    where
        F: Fn(&WatchdogConfig) -> HealthReport + Send + 'static,
    {
        let stop = Arc::new(AtomicBool::new(false));
        let board = Arc::new(Mutex::new(HealthReport::default()));
        let (stop_t, board_t) = (Arc::clone(&stop), Arc::clone(&board));
        let handle = std::thread::Builder::new()
            .name("tcbnn-watchdog".to_string())
            .spawn(move || {
                while !stop_t.load(Ordering::Acquire) {
                    *board_t.lock().unwrap() = probe(&cfg);
                    // sleep the period in short slices so stop() joins
                    // promptly even with a long probe interval
                    let until = Instant::now() + cfg.period;
                    while !stop_t.load(Ordering::Acquire) {
                        let left = until.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            break;
                        }
                        std::thread::sleep(left.min(Duration::from_millis(20)));
                    }
                }
            })
            .expect("spawn watchdog");
        Watchdog { stop, board, handle: Some(handle) }
    }

    /// The latest published report (empty until the first probe lands).
    pub fn report(&self) -> HealthReport {
        self.board.lock().unwrap().clone()
    }

    /// Stop and join the monitor thread (also happens on drop).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn live_probe() -> ShardProbe {
        ShardProbe {
            started: true,
            exited: false,
            heartbeat_age: Some(Duration::from_millis(5)),
            queue_depth: 0,
            oldest_queue_age: None,
        }
    }

    #[test]
    fn classification_priority_order() {
        let cfg = WatchdogConfig::default();
        // live, fresh heartbeat, empty queue, no SLO: healthy
        assert_eq!(classify(&live_probe(), None, &cfg), ShardState::Healthy);
        // not started yet: startup grace, even with no heartbeat
        let p = ShardProbe::default();
        assert_eq!(classify(&p, None, &cfg), ShardState::Healthy);
        // exited wins over everything
        let p = ShardProbe { exited: true, ..live_probe() };
        let s = classify(&p, Some(1.0), &cfg);
        assert_eq!(s.name(), "stalled");
        assert_eq!(s.reason(), "worker exited");
        assert!(!s.is_up());
        // stale heartbeat: stalled, even when the queue is fine
        let p = ShardProbe {
            heartbeat_age: Some(Duration::from_secs(2)),
            ..live_probe()
        };
        let s = classify(&p, None, &cfg);
        assert_eq!(s.name(), "stalled");
        assert!(s.reason().contains("no heartbeat"), "{}", s.reason());
        // old queue on a live shard: degraded (still up)
        let p = ShardProbe {
            queue_depth: 9,
            oldest_queue_age: Some(Duration::from_secs(1)),
            ..live_probe()
        };
        let s = classify(&p, None, &cfg);
        assert_eq!(s.name(), "degraded");
        assert!(s.is_up());
        // windowed SLO miss-rate: degraded only past the threshold
        assert_eq!(classify(&live_probe(), Some(0.5), &cfg), ShardState::Healthy);
        let s = classify(&live_probe(), Some(0.51), &cfg);
        assert_eq!(s.name(), "degraded");
        assert!(s.reason().contains("SLO"), "{}", s.reason());
    }

    #[test]
    fn report_rollups_and_attr_lowering() {
        let healthy = ShardHealth {
            shard: 0,
            state: ShardState::Healthy,
            heartbeat_age_s: 0.004,
            queue_depth: 1,
        };
        let stalled = ShardHealth {
            shard: 1,
            state: ShardState::Stalled { reason: "worker exited".to_string() },
            heartbeat_age_s: 3.0,
            queue_depth: 7,
        };
        let degraded = ShardHealth {
            shard: 0,
            state: ShardState::Degraded { reason: "x".to_string() },
            heartbeat_age_s: 0.01,
            queue_depth: 2,
        };
        let r = HealthReport {
            models: vec![ModelHealth {
                model: "m".to_string(),
                shards: vec![healthy.clone(), stalled.clone()],
            }],
        };
        assert!(!r.all_up());
        assert!(!r.all_healthy());
        let attrs = r.attrs_for("m");
        assert_eq!(attrs.len(), 2);
        assert_eq!(attrs[1].state, "stalled");
        assert_eq!(attrs[1].reason, "worker exited");
        assert_eq!(attrs[1].queue_depth, 7);
        assert!(!attrs[1].is_up());
        assert!(r.attrs_for("nope").is_empty());
        let r = HealthReport {
            models: vec![ModelHealth {
                model: "m".to_string(),
                shards: vec![healthy, degraded],
            }],
        };
        assert!(r.all_up(), "degraded still serves traffic");
        assert!(!r.all_healthy());
    }

    #[test]
    fn watchdog_publishes_and_stops() {
        use std::sync::atomic::AtomicU64;
        let probes = Arc::new(AtomicU64::new(0));
        let p = Arc::clone(&probes);
        let cfg = WatchdogConfig {
            period: Duration::from_millis(5),
            ..Default::default()
        };
        let mut wd = Watchdog::spawn(cfg, move |_| {
            p.fetch_add(1, Ordering::Relaxed);
            HealthReport {
                models: vec![ModelHealth { model: "m".to_string(), shards: vec![] }],
            }
        });
        let deadline = Instant::now() + Duration::from_secs(10);
        while wd.report().models.is_empty() {
            assert!(Instant::now() < deadline, "watchdog never published");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(wd.report().models[0].model, "m");
        wd.stop();
        let after = probes.load(Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(probes.load(Ordering::Relaxed), after, "stopped probing");
    }
}
