//! The `Fleet`: N named models, each served by a pool of replica
//! shards with work stealing, behind admission control and SLO-aware
//! batch sizing.  See `docs/SERVING.md` for the architecture.
//!
//! Each registered model owns `shards` worker threads.  A worker
//! builds its own model instance via the registration factory (so
//! `EngineModel` replicas can share one `PlanCache`/calibration
//! profile but keep private arenas), then loops: form a batch from its
//! own queue; else steal the oldest queued requests from the deepest
//! sibling; else sleep until the flush deadline or a submit wakes it.
//!
//! The submit path is synchronous about rejection: admission control
//! (token bucket + total queue depth) runs *before* anything is
//! enqueued, so a shed request returns [`FleetError::Overloaded`] and
//! never leaves a waiter behind.  Accepted requests carry their
//! response sender with them through the queues — a steal moves the
//! waiter along with the work.
//!
//! Lost-wakeup safety: `submit` pushes, then notifies under the wake
//! lock; a worker about to sleep holds that lock and re-probes the
//! queue depth mirrors first.  A bounded sleep (the flush deadline,
//! capped at 10ms) backstops everything else.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::RouteError;
use crate::coordinator::server::{BatchModel, Response};
use crate::obs::export::{ShardAttr, Snapshot};
use crate::obs::trace::{BatchTrace, Span};

use super::admission::{Admission, AdmissionConfig, Overload};
use super::queue::{FleetReq, Formed, ShardQueue};
use super::slo::{BatchSecsPredictor, BatchSizer, SloConfig};

/// Idle poll bound: the longest a worker sleeps without re-scanning
/// for steal opportunities (also the lost-wakeup backstop).
const IDLE_POLL: Duration = Duration::from_millis(10);

/// Batches between a worker's engine-snapshot publications (the
/// per-shard `obs_snapshot` graft is also refreshed on exit).
const ENGINE_PUBLISH_EVERY: u64 = 8;

/// Why a fleet submit failed.  Routing failures reuse the
/// coordinator's typed [`RouteError`]; overload is the admission
/// layer's explicit rejection.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FleetError {
    Route(RouteError),
    Overloaded(Overload),
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::Route(e) => write!(f, "{e}"),
            FleetError::Overloaded(o) => write!(f, "overloaded: {o}"),
        }
    }
}

impl std::error::Error for FleetError {}

impl From<RouteError> for FleetError {
    fn from(e: RouteError) -> FleetError {
        FleetError::Route(e)
    }
}

/// Per-model serving configuration.
#[derive(Clone)]
pub struct FleetModelConfig {
    /// replica shards (worker threads), >= 1
    pub shards: usize,
    /// max time a straggler may wait before a partial batch flushes
    pub max_wait: Duration,
    pub admission: AdmissionConfig,
    /// when set (together with `predictor`), batch sizing is
    /// SLO-restricted; see `serve::slo`
    pub slo: Option<SloConfig>,
    /// predicted service seconds per bucket (e.g.
    /// [`super::slo::plan_predictor`]); absent -> fixed buckets
    pub predictor: Option<BatchSecsPredictor>,
}

impl Default for FleetModelConfig {
    fn default() -> Self {
        FleetModelConfig {
            shards: 2,
            max_wait: Duration::from_millis(2),
            admission: AdmissionConfig::default(),
            slo: None,
            predictor: None,
        }
    }
}

/// Per-shard counters + the shard's latest engine-side snapshot.
struct ShardStats {
    requests: AtomicU64,
    batches: AtomicU64,
    steals: AtomicU64,
    engine: Mutex<Option<Snapshot>>,
}

impl ShardStats {
    fn new() -> ShardStats {
        ShardStats {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            engine: Mutex::new(None),
        }
    }
}

/// Everything one model's submit path and workers share.
struct ModelShared {
    name: String,
    max_wait: Duration,
    queues: Vec<ShardQueue>,
    stats: Vec<ShardStats>,
    metrics: Arc<Metrics>,
    admission: Admission,
    sheds: AtomicU64,
    slo_hits: AtomicU64,
    slo_misses: AtomicU64,
    slo: Option<SloConfig>,
    predictor: Option<BatchSecsPredictor>,
    /// set by shard 0 once its sizer is built (observability + tests)
    sizer_restricted: AtomicBool,
    next_id: AtomicU64,
    rr: AtomicUsize,
    shutdown: AtomicBool,
    wake: Mutex<()>,
    cv: Condvar,
}

impl ModelShared {
    fn total_depth(&self) -> usize {
        self.queues.iter().map(|q| q.depth()).sum()
    }

    /// Wake every worker.  Taking the wake lock orders this after the
    /// caller's queue push: a worker about to sleep holds the lock and
    /// re-probes the depth mirrors first, so a push either lands before
    /// that probe or its notify lands after the worker starts waiting —
    /// never between (no lost wakeup).
    fn notify(&self) {
        let _g = self.wake.lock().unwrap();
        self.cv.notify_all();
    }

    /// Is there anything a worker could act on right now?  Cheap
    /// (atomic depth probes only) — called under the wake lock before
    /// sleeping.  Own stragglers below a bucket are deliberately not a
    /// wake reason: they only become actionable at the flush deadline,
    /// which bounds the sleep instead.
    fn has_work(&self, shard: usize, min_bucket: usize) -> bool {
        if self.shutdown.load(Ordering::Acquire) {
            return true;
        }
        if self.queues[shard].depth() >= min_bucket {
            return true; // a full bucket landed at home since the scan
        }
        self.queues
            .iter()
            .enumerate()
            .any(|(i, q)| i != shard && q.depth() >= min_bucket)
    }
}

/// The fleet router: owns every model's shards; submit by name.
pub struct Fleet {
    models: HashMap<String, Arc<ModelShared>>,
    workers: Vec<JoinHandle<()>>,
}

impl Default for Fleet {
    fn default() -> Self {
        Fleet::new()
    }
}

impl Fleet {
    pub fn new() -> Fleet {
        Fleet { models: HashMap::new(), workers: Vec::new() }
    }

    /// Register a model under `name` with `cfg.shards` replicas.  The
    /// factory runs once inside each shard's worker thread; replicas
    /// meant to share a plan cache should close over one (pre-warmed)
    /// `PlanCache`.
    pub fn register<F>(&mut self, name: &str, cfg: FleetModelConfig, factory: F)
    where
        F: Fn() -> Result<Box<dyn BatchModel>> + Send + Sync + Clone + 'static,
    {
        assert!(cfg.shards > 0, "a model needs at least one shard");
        assert!(
            !self.models.contains_key(name),
            "model {name:?} already registered"
        );
        let shared = Arc::new(ModelShared {
            name: name.to_string(),
            max_wait: cfg.max_wait,
            queues: (0..cfg.shards).map(|_| ShardQueue::new()).collect(),
            stats: (0..cfg.shards).map(|_| ShardStats::new()).collect(),
            metrics: Arc::new(Metrics::new()),
            admission: Admission::new(cfg.admission),
            sheds: AtomicU64::new(0),
            slo_hits: AtomicU64::new(0),
            slo_misses: AtomicU64::new(0),
            slo: cfg.slo,
            predictor: cfg.predictor,
            sizer_restricted: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            wake: Mutex::new(()),
            cv: Condvar::new(),
        });
        for shard in 0..cfg.shards {
            let sh = Arc::clone(&shared);
            let f = factory.clone();
            let handle = std::thread::Builder::new()
                .name(format!("tcbnn-fleet-{name}-{shard}"))
                .spawn(move || worker_loop(sh, shard, f))
                .expect("spawn fleet worker");
            self.workers.push(handle);
        }
        self.models.insert(name.to_string(), shared);
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Submit one request.  Synchronous rejection: a returned `Err` was
    /// never enqueued (no leaked waiter); an `Ok` receiver is answered
    /// by whichever shard executes the request (possibly after a
    /// steal), or disconnects if the fleet is torn down around it.
    pub fn submit(
        &self,
        model: &str,
        input: Vec<f32>,
    ) -> Result<Receiver<Response>, FleetError> {
        let Some(m) = self.models.get(model) else {
            return Err(RouteError::UnknownModel {
                requested: model.to_string(),
                registered: self.model_names(),
            }
            .into());
        };
        if m.shutdown.load(Ordering::Acquire) {
            return Err(RouteError::Shutdown { model: model.to_string() }.into());
        }
        if let Err(o) = m.admission.try_admit(m.total_depth(), Instant::now()) {
            m.sheds.fetch_add(1, Ordering::Relaxed);
            return Err(FleetError::Overloaded(o));
        }
        let (rtx, rrx) = channel();
        let id = m.next_id.fetch_add(1, Ordering::Relaxed);
        let shard = m.rr.fetch_add(1, Ordering::Relaxed) % m.queues.len();
        m.queues[shard].push(FleetReq {
            id,
            input,
            enqueued: Instant::now(),
            tx: rtx,
        });
        m.notify();
        Ok(rrx)
    }

    /// The model's fleet-level metrics sink (request latencies,
    /// batches, traces).
    pub fn metrics(&self, model: &str) -> Option<Arc<Metrics>> {
        self.models.get(model).map(|m| Arc::clone(&m.metrics))
    }

    /// Requests shed by admission control.
    pub fn sheds(&self, model: &str) -> Option<u64> {
        self.models.get(model).map(|m| m.sheds.load(Ordering::Relaxed))
    }

    /// Steal operations across the model's shards.
    pub fn steals(&self, model: &str) -> Option<u64> {
        self.models.get(model).map(|m| {
            m.stats.iter().map(|s| s.steals.load(Ordering::Relaxed)).sum()
        })
    }

    /// `(hits, misses)` against the configured p99 deadline.
    pub fn slo_counts(&self, model: &str) -> Option<(u64, u64)> {
        self.models.get(model).map(|m| {
            (
                m.slo_hits.load(Ordering::Relaxed),
                m.slo_misses.load(Ordering::Relaxed),
            )
        })
    }

    /// Whether the model's SLO actually restricted its bucket list
    /// (false until shard 0 has built its sizer).
    pub fn slo_restricted(&self, model: &str) -> Option<bool> {
        self.models
            .get(model)
            .map(|m| m.sizer_restricted.load(Ordering::Acquire))
    }

    /// One model's full telemetry snapshot: the fleet `Metrics`
    /// rendering plus sheds/steals/SLO counters, per-shard attribution,
    /// and the engine-side graft (throughput counters summed across
    /// shard replicas; per-layer attribution from the busiest shard).
    pub fn snapshot(&self, model: &str) -> Option<Snapshot> {
        let m = self.models.get(model)?;
        let mut snap = m.metrics.snapshot();
        snap.sheds = m.sheds.load(Ordering::Relaxed);
        snap.steals = m
            .stats
            .iter()
            .map(|s| s.steals.load(Ordering::Relaxed))
            .sum();
        snap.slo_hits = m.slo_hits.load(Ordering::Relaxed);
        snap.slo_misses = m.slo_misses.load(Ordering::Relaxed);
        snap.shards = m
            .stats
            .iter()
            .enumerate()
            .map(|(i, s)| ShardAttr {
                shard: i,
                requests: s.requests.load(Ordering::Relaxed),
                batches: s.batches.load(Ordering::Relaxed),
                steals: s.steals.load(Ordering::Relaxed),
            })
            .collect();
        let engines: Vec<Snapshot> = m
            .stats
            .iter()
            .filter_map(|s| s.engine.lock().unwrap().clone())
            .collect();
        if let Some(busiest) = engines
            .iter()
            .max_by(|a, b| a.engine_busy_s.partial_cmp(&b.engine_busy_s).unwrap())
        {
            // attribution (layers, drift, plan-cache counters) from the
            // busiest replica; pure throughput counters summed
            snap.absorb_engine(busiest);
            snap.engine_rows = engines.iter().map(|e| e.engine_rows).sum();
            snap.engine_busy_s = engines.iter().map(|e| e.engine_busy_s).sum();
            snap.replans = engines.iter().map(|e| e.replans).sum();
        }
        Some(snap)
    }

    /// Per-model report lines (name-sorted).
    pub fn report(&self) -> String {
        self.model_names()
            .into_iter()
            .map(|name| {
                let snap = self.snapshot(&name).expect("registered");
                format!("{name}: {}", snap.render_report())
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Flag every model as shutting down and wake all workers.  After
    /// this, `submit` returns `RouteError::Shutdown`; workers flush
    /// their remaining queues and exit.  (`shutdown` joins them.)
    pub fn begin_shutdown(&self) {
        for m in self.models.values() {
            m.shutdown.store(true, Ordering::Release);
            m.notify();
        }
    }

    /// Drain and stop: queued requests are flushed (their waiters get
    /// responses), then workers exit and are joined.
    pub fn shutdown(mut self) {
        self.begin_shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.begin_shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop<F>(shared: Arc<ModelShared>, shard: usize, factory: F)
where
    F: Fn() -> Result<Box<dyn BatchModel>>,
{
    // a failed factory ends this shard cleanly; siblings keep serving
    // (and can steal this shard's queue), mirroring the coordinator
    // worker's behavior
    let mut model = match factory() {
        Ok(m) => m,
        Err(e) => {
            eprintln!(
                "tcbnn-fleet-{}-{shard}: model factory failed, shard exiting: {e:#}",
                shared.name
            );
            return;
        }
    };
    let row_elems = model.row_elems();
    let out_elems = model.out_elems();
    let sizer = BatchSizer::for_model(
        model.buckets(),
        shared.slo,
        shared.predictor.as_ref(),
    );
    if shard == 0 {
        shared
            .sizer_restricted
            .store(sizer.restricted(), Ordering::Release);
    }
    let mut batches_run = 0u64;
    loop {
        let shutting = shared.shutdown.load(Ordering::Acquire);
        let now = Instant::now();
        // 1. form from the own queue (forced flush while draining)
        if let Some(formed) = shared.queues[shard].try_form(
            sizer.buckets(),
            row_elems,
            shared.max_wait,
            now,
            shutting,
        ) {
            run_batch(&shared, shard, model.as_mut(), formed, out_elems);
            batches_run += 1;
            if batches_run % ENGINE_PUBLISH_EVERY == 0 {
                publish_engine(&shared, shard, model.as_ref());
            }
            continue;
        }
        // 2. nothing formable at home: steal the deepest sibling's
        //    oldest requests (up to one admissible batch's worth).
        //    During shutdown each shard drains only its own queue.
        if !shutting && steal_from_sibling(&shared, shard, &sizer) {
            shared.stats[shard].steals.fetch_add(1, Ordering::Relaxed);
            continue; // the stolen work is now formable at home
        }
        if shutting {
            // own queue fully drained (forced flush forms any tail)
            publish_engine(&shared, shard, model.as_ref());
            return;
        }
        // 3. sleep until the flush deadline / a submit's wake, capped
        //    by the idle poll (which also bounds steal-scan latency)
        let wait = shared.queues[shard]
            .time_until_flush(shared.max_wait, Instant::now())
            .unwrap_or(IDLE_POLL)
            .min(IDLE_POLL)
            .max(Duration::from_micros(100));
        let guard = shared.wake.lock().unwrap();
        // no lost wakeup: submit notifies under this lock after its
        // push, so anything that arrived since our scan is visible to
        // this re-probe, or its notify lands after we start waiting
        if shared.has_work(shard, sizer.min_bucket()) {
            drop(guard);
            continue;
        }
        let _ = shared.cv.wait_timeout(guard, wait).unwrap();
    }
}

/// Move up to one batch's worth of the deepest sibling's oldest
/// requests into `shard`'s queue.  Only called when `shard` cannot
/// form a batch, so a successful steal is immediately consumed (no
/// ping-pong: the minimum steal is a formable bucket's worth or the
/// victim's whole backlog).
fn steal_from_sibling(
    shared: &ModelShared,
    shard: usize,
    sizer: &BatchSizer,
) -> bool {
    let Some((victim, depth)) = shared
        .queues
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != shard)
        .map(|(i, q)| (i, q.depth()))
        .max_by_key(|&(_, d)| d)
    else {
        return false; // single shard: nobody to steal from
    };
    if depth < sizer.min_bucket() {
        return false;
    }
    let stolen = shared.queues[victim].pop_front_n(sizer.max_bucket().min(depth));
    if stolen.is_empty() {
        return false; // raced another thief
    }
    for r in stolen {
        shared.queues[shard].push(r);
    }
    true
}

/// Execute one formed batch and answer its waiters.
fn run_batch(
    shared: &ModelShared,
    shard: usize,
    model: &mut dyn BatchModel,
    formed: Formed,
    out_elems: usize,
) {
    let Formed { reqs, data, padded, oldest_wait } = formed;
    let logits = model.run_batch(&data, padded).expect("fleet model run");
    let done = Instant::now();
    let lats: Vec<f64> = reqs
        .iter()
        .map(|r| done.duration_since(r.enqueued).as_secs_f64())
        .collect();
    shared.metrics.record_batch(reqs.len(), padded, &lats);
    let mut spans = Vec::with_capacity(1 + 4);
    spans.push(Span::queue(oldest_wait.as_secs_f64()));
    spans.extend(model.layer_spans());
    shared.metrics.traces().push(BatchTrace {
        seq: shared.metrics.batches(),
        ids: reqs.iter().map(|r| r.id).collect(),
        spans,
    });
    if let Some(slo) = shared.slo {
        let d = slo.p99_deadline.as_secs_f64();
        for &l in &lats {
            if l <= d {
                shared.slo_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                shared.slo_misses.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    let st = &shared.stats[shard];
    st.requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
    st.batches.fetch_add(1, Ordering::Relaxed);
    for (row, req) in reqs.into_iter().enumerate() {
        let l = logits[row * out_elems..(row + 1) * out_elems].to_vec();
        let argmax = l
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        // a receiver the client dropped is fine — send errors ignored
        let _ = req.tx.send(Response {
            id: req.id,
            logits: l,
            argmax,
            latency: Duration::from_secs_f64(lats[row]),
        });
    }
}

/// Refresh this shard's engine-side snapshot slot (None for models
/// without engine telemetry, e.g. mocks).
fn publish_engine(shared: &ModelShared, shard: usize, model: &dyn BatchModel) {
    *shared.stats[shard].engine.lock().unwrap() = model.obs_snapshot();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::MockModel;

    fn mock_factory(
        delay: Duration,
    ) -> impl Fn() -> Result<Box<dyn BatchModel>> + Send + Sync + Clone + 'static {
        move || {
            Ok(Box::new(MockModel { row_elems: 4, out_elems: 3, delay })
                as Box<dyn BatchModel>)
        }
    }

    #[test]
    fn serves_and_answers_every_accepted_request() {
        let mut fleet = Fleet::new();
        fleet.register("m", FleetModelConfig::default(), mock_factory(Duration::ZERO));
        let rxs: Vec<_> = (0..100)
            .map(|i| fleet.submit("m", vec![i as f32; 4]).expect("admitted"))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(30)).expect("answered");
            assert_eq!(r.logits[0], (i * 4) as f32, "request {i} got its own answer");
        }
        assert_eq!(fleet.metrics("m").unwrap().completed(), 100);
        assert_eq!(fleet.sheds("m"), Some(0));
    }

    #[test]
    fn unknown_and_shutdown_are_typed() {
        let mut fleet = Fleet::new();
        fleet.register("m", FleetModelConfig::default(), mock_factory(Duration::ZERO));
        match fleet.submit("nope", vec![]) {
            Err(FleetError::Route(RouteError::UnknownModel { requested, registered })) => {
                assert_eq!(requested, "nope");
                assert_eq!(registered, vec!["m".to_string()]);
            }
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        fleet.begin_shutdown();
        match fleet.submit("m", vec![0.0; 4]) {
            Err(FleetError::Route(RouteError::Shutdown { model })) => {
                assert_eq!(model, "m");
            }
            other => panic!("expected Shutdown, got {other:?}"),
        }
    }

    #[test]
    fn shutdown_flushes_pending_waiters() {
        let mut fleet = Fleet::new();
        fleet.register("m", FleetModelConfig::default(), mock_factory(Duration::ZERO));
        // 3 stragglers (below the smallest bucket): only the shutdown
        // drain's forced flush can answer them
        let rxs: Vec<_> = (0..3)
            .map(|i| fleet.submit("m", vec![i as f32; 4]).unwrap())
            .collect();
        fleet.shutdown();
        for rx in rxs {
            rx.recv().expect("flushed on shutdown, not leaked");
        }
    }

    #[test]
    fn idle_shard_steals_from_a_loaded_sibling() {
        let mut fleet = Fleet::new();
        fleet.register(
            "m",
            FleetModelConfig { shards: 2, ..Default::default() },
            // slow batches so the loaded shard stays loaded while the
            // idle one wakes up
            mock_factory(Duration::from_millis(20)),
        );
        // bypass round-robin dispatch: pile every request onto shard 0
        let shared = Arc::clone(&fleet.models["m"]);
        let rxs: Vec<_> = (0..64)
            .map(|i| {
                let (tx, rx) = channel();
                shared.queues[0].push(FleetReq {
                    id: i,
                    input: vec![i as f32; 4],
                    enqueued: Instant::now(),
                    tx,
                });
                rx
            })
            .collect();
        shared.notify();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).expect("answered");
        }
        assert!(
            fleet.steals("m").unwrap() >= 1,
            "shard 1 must have stolen from shard 0's 64-deep queue"
        );
        // both shards did real work
        let snap = fleet.snapshot("m").unwrap();
        assert_eq!(snap.shards.len(), 2);
        assert!(snap.shards.iter().all(|s| s.requests > 0), "{:?}", snap.shards);
        assert_eq!(snap.steals, fleet.steals("m").unwrap());
        assert_eq!(snap.requests, 64);
    }

    #[test]
    fn depth_overload_sheds_synchronously() {
        let mut fleet = Fleet::new();
        fleet.register(
            "m",
            FleetModelConfig {
                shards: 1,
                admission: AdmissionConfig {
                    rate: None,
                    burst: 0.0,
                    max_queue_depth: 8,
                },
                ..Default::default()
            },
            // slow enough that the queue genuinely backs up
            mock_factory(Duration::from_millis(50)),
        );
        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for i in 0..200 {
            match fleet.submit("m", vec![i as f32; 4]) {
                Ok(rx) => accepted.push(rx),
                Err(FleetError::Overloaded(Overload::QueueFull)) => shed += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(shed > 0, "depth limit must shed under this burst");
        assert_eq!(fleet.sheds("m"), Some(shed));
        // zero lost waiters: every accepted request is answered
        for rx in accepted {
            rx.recv_timeout(Duration::from_secs(60)).expect("accepted => answered");
        }
    }
}
