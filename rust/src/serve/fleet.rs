//! The `Fleet`: N named models, each served by a pool of replica
//! shards with work stealing, behind admission control and SLO-aware
//! batch sizing.  See `docs/SERVING.md` for the architecture.
//!
//! Each registered model owns `shards` worker threads.  A worker
//! builds its own model instance via the registration factory (so
//! `EngineModel` replicas can share one `PlanCache`/calibration
//! profile but keep private arenas), then loops: form a batch from its
//! own queue; else steal the oldest queued requests from the deepest
//! sibling; else sleep until the flush deadline or a submit wakes it.
//!
//! The submit path is synchronous about rejection: priority shedding
//! (a low-priority model yields when higher-priority backlog crosses
//! the fleet's pressure threshold) and admission control (token bucket
//! + total queue depth) run *before* anything is enqueued, so a shed
//! request returns [`FleetError::Overloaded`] and never leaves a
//! waiter behind.  Accepted requests carry their
//! response sender with them through the queues — a steal moves the
//! waiter along with the work.
//!
//! Lost-wakeup safety: `submit` pushes, then notifies under the wake
//! lock; a worker about to sleep holds that lock and re-probes the
//! queue depth mirrors first.  A bounded sleep (the flush deadline,
//! capped at 10ms) backstops everything else.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::RouteError;
use crate::coordinator::server::{BatchModel, Response};
use crate::obs::export::{LayerAttr, RepackEdge, ShardAttr, Snapshot};
use crate::obs::scrape::ScrapeSource;
use crate::obs::trace::{BatchTrace, Span};
use crate::obs::tracelog::{RequestTrace, TraceWriter};

use super::admission::{Admission, AdmissionConfig, Overload};
use super::health::{
    classify, HealthReport, ModelHealth, ShardHealth, ShardProbe, Watchdog,
    WatchdogConfig,
};
use super::queue::{FleetReq, Formed, ShardQueue};
use super::slo::{BatchSecsPredictor, BatchSizer, SloConfig};

/// Idle poll bound: the longest a worker sleeps without re-scanning
/// for steal opportunities (also the lost-wakeup backstop).
const IDLE_POLL: Duration = Duration::from_millis(10);

/// Batches between a worker's engine-snapshot publications (the
/// per-shard `obs_snapshot` graft is also refreshed on exit).
const ENGINE_PUBLISH_EVERY: u64 = 8;

/// Why a fleet submit failed.  Routing failures reuse the
/// coordinator's typed [`RouteError`]; overload is the admission
/// layer's explicit rejection.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FleetError {
    Route(RouteError),
    Overloaded(Overload),
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::Route(e) => write!(f, "{e}"),
            FleetError::Overloaded(o) => write!(f, "overloaded: {o}"),
        }
    }
}

impl std::error::Error for FleetError {}

impl From<RouteError> for FleetError {
    fn from(e: RouteError) -> FleetError {
        FleetError::Route(e)
    }
}

/// Per-model serving configuration.
#[derive(Clone)]
pub struct FleetModelConfig {
    /// replica shards (worker threads), >= 1
    pub shards: usize,
    /// shared-host scheduling class: 0 (default) is highest priority
    /// and never priority-shed; a model with priority N > 0 sheds new
    /// submits ([`Overload::LowPriority`]) whenever the total backlog
    /// across strictly-higher-priority models (priority < N) reaches
    /// the fleet's pressure threshold — background work yields the
    /// host to critical work first
    pub priority: u8,
    /// max time a straggler may wait before a partial batch flushes
    pub max_wait: Duration,
    pub admission: AdmissionConfig,
    /// when set (together with `predictor`), batch sizing is
    /// SLO-restricted; see `serve::slo`
    pub slo: Option<SloConfig>,
    /// predicted service seconds per bucket (e.g.
    /// [`super::slo::plan_predictor`]); absent -> fixed buckets
    pub predictor: Option<BatchSecsPredictor>,
    /// sampled JSONL request-trace sink shared by this model's shards
    /// (see `obs::tracelog`); absent -> no trace log
    pub trace: Option<Arc<TraceWriter>>,
}

impl Default for FleetModelConfig {
    fn default() -> Self {
        FleetModelConfig {
            shards: 2,
            priority: 0,
            max_wait: Duration::from_millis(2),
            admission: AdmissionConfig::default(),
            slo: None,
            predictor: None,
            trace: None,
        }
    }
}

/// `heartbeat_ns` sentinel: the worker has not beaten yet.
const NO_HEARTBEAT: u64 = u64::MAX;

/// Per-shard counters + the shard's latest engine-side snapshot.
struct ShardStats {
    requests: AtomicU64,
    batches: AtomicU64,
    steals: AtomicU64,
    engine: Mutex<Option<Snapshot>>,
    /// worker liveness for the watchdog: the thread has entered its
    /// loop / has returned, and its last loop-top timestamp as
    /// nanoseconds since `ModelShared::epoch` (`NO_HEARTBEAT` = never)
    started: AtomicBool,
    exited: AtomicBool,
    heartbeat_ns: AtomicU64,
}

impl ShardStats {
    fn new() -> ShardStats {
        ShardStats {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            engine: Mutex::new(None),
            started: AtomicBool::new(false),
            exited: AtomicBool::new(false),
            heartbeat_ns: AtomicU64::new(NO_HEARTBEAT),
        }
    }

    fn beat(&self, epoch: Instant) {
        self.heartbeat_ns
            .store(epoch.elapsed().as_nanos() as u64, Ordering::Release);
    }

    /// Age of the last heartbeat (`None` before the first).
    fn heartbeat_age(&self, epoch: Instant, now: Instant) -> Option<Duration> {
        let ns = self.heartbeat_ns.load(Ordering::Acquire);
        if ns == NO_HEARTBEAT {
            return None;
        }
        Some(now.saturating_duration_since(epoch + Duration::from_nanos(ns)))
    }
}

/// Everything one model's submit path and workers share.
struct ModelShared {
    name: String,
    /// shared-host scheduling class (0 = highest, never priority-shed)
    priority: u8,
    max_wait: Duration,
    queues: Vec<ShardQueue>,
    stats: Vec<ShardStats>,
    metrics: Arc<Metrics>,
    admission: Admission,
    /// time origin for the heartbeat nanosecond stamps
    epoch: Instant,
    /// sampled request-trace sink (None: no trace log)
    trace: Option<Arc<TraceWriter>>,
    sheds: AtomicU64,
    /// subset of `sheds`: rejections because this model yielded to
    /// higher-priority backlog
    priority_sheds: AtomicU64,
    slo_hits: AtomicU64,
    slo_misses: AtomicU64,
    slo: Option<SloConfig>,
    predictor: Option<BatchSecsPredictor>,
    /// set by shard 0 once its sizer is built (observability + tests)
    sizer_restricted: AtomicBool,
    next_id: AtomicU64,
    rr: AtomicUsize,
    shutdown: AtomicBool,
    wake: Mutex<()>,
    cv: Condvar,
}

impl ModelShared {
    fn total_depth(&self) -> usize {
        self.queues.iter().map(|q| q.depth()).sum()
    }

    /// Wake every worker.  Taking the wake lock orders this after the
    /// caller's queue push: a worker about to sleep holds the lock and
    /// re-probes the depth mirrors first, so a push either lands before
    /// that probe or its notify lands after the worker starts waiting —
    /// never between (no lost wakeup).
    fn notify(&self) {
        let _g = self.wake.lock().unwrap();
        self.cv.notify_all();
    }

    /// Is there anything a worker could act on right now?  Cheap
    /// (atomic depth probes only) — called under the wake lock before
    /// sleeping.  Own stragglers below a bucket are deliberately not a
    /// wake reason: they only become actionable at the flush deadline,
    /// which bounds the sleep instead.
    fn has_work(&self, shard: usize, min_bucket: usize) -> bool {
        if self.shutdown.load(Ordering::Acquire) {
            return true;
        }
        if self.queues[shard].depth() >= min_bucket {
            return true; // a full bucket landed at home since the scan
        }
        self.queues
            .iter()
            .enumerate()
            .any(|(i, q)| i != shard && q.depth() >= min_bucket)
    }
}

/// Default [`Fleet::set_priority_pressure`] threshold: the
/// higher-priority backlog (total queued requests) at which
/// lower-priority submits start shedding.
const DEFAULT_PRIORITY_PRESSURE: usize = 64;

/// The fleet router: owns every model's shards; submit by name.
pub struct Fleet {
    models: HashMap<String, Arc<ModelShared>>,
    workers: Vec<JoinHandle<()>>,
    watchdog: Mutex<Option<Watchdog>>,
    /// higher-priority backlog depth at which low-priority submits shed
    priority_pressure: usize,
}

impl Default for Fleet {
    fn default() -> Self {
        Fleet::new()
    }
}

impl Fleet {
    pub fn new() -> Fleet {
        Fleet {
            models: HashMap::new(),
            workers: Vec::new(),
            watchdog: Mutex::new(None),
            priority_pressure: DEFAULT_PRIORITY_PRESSURE,
        }
    }

    /// Set the shared-host pressure threshold: when the total backlog
    /// across models of priority < N reaches `depth`, submits to
    /// priority-N models (N > 0) shed with [`Overload::LowPriority`].
    /// Priority-0 models are never priority-shed.
    pub fn set_priority_pressure(&mut self, depth: usize) {
        self.priority_pressure = depth.max(1);
    }

    /// Register a model under `name` with `cfg.shards` replicas.  The
    /// factory runs once inside each shard's worker thread; replicas
    /// meant to share a plan cache should close over one (pre-warmed)
    /// `PlanCache`.
    pub fn register<F>(&mut self, name: &str, cfg: FleetModelConfig, factory: F)
    where
        F: Fn() -> Result<Box<dyn BatchModel>> + Send + Sync + Clone + 'static,
    {
        assert!(cfg.shards > 0, "a model needs at least one shard");
        assert!(
            !self.models.contains_key(name),
            "model {name:?} already registered"
        );
        let shared = Arc::new(ModelShared {
            name: name.to_string(),
            priority: cfg.priority,
            max_wait: cfg.max_wait,
            queues: (0..cfg.shards).map(|_| ShardQueue::new()).collect(),
            stats: (0..cfg.shards).map(|_| ShardStats::new()).collect(),
            metrics: Arc::new(Metrics::new()),
            admission: Admission::new(cfg.admission),
            epoch: Instant::now(),
            trace: cfg.trace,
            sheds: AtomicU64::new(0),
            priority_sheds: AtomicU64::new(0),
            slo_hits: AtomicU64::new(0),
            slo_misses: AtomicU64::new(0),
            slo: cfg.slo,
            predictor: cfg.predictor,
            sizer_restricted: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            wake: Mutex::new(()),
            cv: Condvar::new(),
        });
        for shard in 0..cfg.shards {
            let sh = Arc::clone(&shared);
            let f = factory.clone();
            let handle = std::thread::Builder::new()
                .name(format!("tcbnn-fleet-{name}-{shard}"))
                .spawn(move || worker_loop(sh, shard, f))
                .expect("spawn fleet worker");
            self.workers.push(handle);
        }
        self.models.insert(name.to_string(), shared);
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Submit one request.  Synchronous rejection: a returned `Err` was
    /// never enqueued (no leaked waiter); an `Ok` receiver is answered
    /// by whichever shard executes the request (possibly after a
    /// steal), or disconnects if the fleet is torn down around it.
    pub fn submit(
        &self,
        model: &str,
        input: Vec<f32>,
    ) -> Result<Receiver<Response>, FleetError> {
        let Some(m) = self.models.get(model) else {
            return Err(RouteError::UnknownModel {
                requested: model.to_string(),
                registered: self.model_names(),
            }
            .into());
        };
        if m.shutdown.load(Ordering::Acquire) {
            return Err(RouteError::Shutdown { model: model.to_string() }.into());
        }
        // priority shedding runs before admission: a yielding request
        // must not burn the model's own rate tokens.  Pressure is the
        // backlog of strictly-higher-priority models on this host.
        if m.priority > 0 {
            let pressure: usize = self
                .models
                .values()
                .filter(|o| o.priority < m.priority)
                .map(|o| o.total_depth())
                .sum();
            if pressure >= self.priority_pressure {
                m.sheds.fetch_add(1, Ordering::Relaxed);
                m.priority_sheds.fetch_add(1, Ordering::Relaxed);
                m.metrics.record_shed();
                return Err(FleetError::Overloaded(Overload::LowPriority));
            }
        }
        if let Err(o) = m.admission.try_admit(m.total_depth(), Instant::now()) {
            m.sheds.fetch_add(1, Ordering::Relaxed);
            m.metrics.record_shed();
            return Err(FleetError::Overloaded(o));
        }
        let (rtx, rrx) = channel();
        let id = m.next_id.fetch_add(1, Ordering::Relaxed);
        let shard = m.rr.fetch_add(1, Ordering::Relaxed) % m.queues.len();
        m.queues[shard].push(FleetReq {
            id,
            input,
            enqueued: Instant::now(),
            steals: 0,
            tx: rtx,
        });
        m.notify();
        Ok(rrx)
    }

    /// The model's fleet-level metrics sink (request latencies,
    /// batches, traces).
    pub fn metrics(&self, model: &str) -> Option<Arc<Metrics>> {
        self.models.get(model).map(|m| Arc::clone(&m.metrics))
    }

    /// Requests shed by admission control (priority sheds included).
    pub fn sheds(&self, model: &str) -> Option<u64> {
        self.models.get(model).map(|m| m.sheds.load(Ordering::Relaxed))
    }

    /// The subset of [`Fleet::sheds`] rejected because this model is
    /// low-priority and higher-priority backlog crossed the pressure
    /// threshold.
    pub fn priority_sheds(&self, model: &str) -> Option<u64> {
        self.models
            .get(model)
            .map(|m| m.priority_sheds.load(Ordering::Relaxed))
    }

    /// Steal operations across the model's shards.
    pub fn steals(&self, model: &str) -> Option<u64> {
        self.models.get(model).map(|m| {
            m.stats.iter().map(|s| s.steals.load(Ordering::Relaxed)).sum()
        })
    }

    /// `(hits, misses)` against the configured p99 deadline.
    pub fn slo_counts(&self, model: &str) -> Option<(u64, u64)> {
        self.models.get(model).map(|m| {
            (
                m.slo_hits.load(Ordering::Relaxed),
                m.slo_misses.load(Ordering::Relaxed),
            )
        })
    }

    /// Whether the model's SLO actually restricted its bucket list
    /// (false until shard 0 has built its sizer).
    pub fn slo_restricted(&self, model: &str) -> Option<bool> {
        self.models
            .get(model)
            .map(|m| m.sizer_restricted.load(Ordering::Acquire))
    }

    /// One model's full telemetry snapshot: the fleet `Metrics`
    /// rendering plus sheds/steals/SLO counters, per-shard attribution,
    /// the engine-side graft merged *across* shard replicas (counters
    /// and per-layer/per-edge attribution summed, not busiest-shard
    /// sampled), and — once the watchdog runs — per-shard health.
    pub fn snapshot(&self, model: &str) -> Option<Snapshot> {
        let m = self.models.get(model)?;
        let mut snap = m.metrics.snapshot();
        snap.sheds = m.sheds.load(Ordering::Relaxed);
        snap.priority_sheds = m.priority_sheds.load(Ordering::Relaxed);
        snap.steals = m
            .stats
            .iter()
            .map(|s| s.steals.load(Ordering::Relaxed))
            .sum();
        snap.slo_hits = m.slo_hits.load(Ordering::Relaxed);
        snap.slo_misses = m.slo_misses.load(Ordering::Relaxed);
        snap.shards = m
            .stats
            .iter()
            .enumerate()
            .map(|(i, s)| ShardAttr {
                shard: i,
                requests: s.requests.load(Ordering::Relaxed),
                batches: s.batches.load(Ordering::Relaxed),
                steals: s.steals.load(Ordering::Relaxed),
            })
            .collect();
        let engines: Vec<Snapshot> = m
            .stats
            .iter()
            .filter_map(|s| s.engine.lock().unwrap().clone())
            .collect();
        if !engines.is_empty() {
            graft_merged_engines(&mut snap, &engines);
        }
        if let Some(report) = self.health_report() {
            snap.health = report.attrs_for(model);
        }
        Some(snap)
    }

    /// Start the shard health watchdog (idempotent: a second call
    /// replaces the first, stopping its thread).  Covers the models
    /// registered so far — call after registration.
    pub fn start_watchdog(&self, cfg: WatchdogConfig) {
        let mut models: Vec<(String, Arc<ModelShared>)> = self
            .models
            .iter()
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect();
        models.sort_by(|a, b| a.0.cmp(&b.0));
        let wd = Watchdog::spawn(cfg, move |cfg| probe_fleet(&models, cfg));
        *self.watchdog.lock().unwrap() = Some(wd);
    }

    /// The watchdog's latest board (`None` until [`Fleet::start_watchdog`];
    /// empty report until its first probe lands).
    pub fn health_report(&self) -> Option<HealthReport> {
        self.watchdog.lock().unwrap().as_ref().map(Watchdog::report)
    }

    /// Per-model report lines (name-sorted).
    pub fn report(&self) -> String {
        self.model_names()
            .into_iter()
            .map(|name| {
                let snap = self.snapshot(&name).expect("registered");
                format!("{name}: {}", snap.render_report())
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Flag every model as shutting down and wake all workers.  After
    /// this, `submit` returns `RouteError::Shutdown`; workers flush
    /// their remaining queues and exit.  (`shutdown` joins them.)  The
    /// watchdog stops first — a draining worker's exit is not a stall.
    pub fn begin_shutdown(&self) {
        drop(self.watchdog.lock().unwrap().take());
        for m in self.models.values() {
            m.shutdown.store(true, Ordering::Release);
            m.notify();
        }
    }

    /// Drain and stop: queued requests are flushed (their waiters get
    /// responses), then workers exit and are joined.
    pub fn shutdown(mut self) {
        self.begin_shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.begin_shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop<F>(shared: Arc<ModelShared>, shard: usize, factory: F)
where
    F: Fn() -> Result<Box<dyn BatchModel>>,
{
    // a failed factory ends this shard cleanly; siblings keep serving
    // (and can steal this shard's queue), mirroring the coordinator
    // worker's behavior.  The watchdog reports the exit as Stalled.
    let mut model = match factory() {
        Ok(m) => m,
        Err(e) => {
            eprintln!(
                "tcbnn-fleet-{}-{shard}: model factory failed, shard exiting: {e:#}",
                shared.name
            );
            shared.stats[shard].exited.store(true, Ordering::Release);
            return;
        }
    };
    let st = &shared.stats[shard];
    st.started.store(true, Ordering::Release);
    st.beat(shared.epoch);
    let row_elems = model.row_elems();
    let out_elems = model.out_elems();
    let mut sizer = BatchSizer::for_model(
        model.buckets(),
        shared.slo,
        shared.predictor.as_ref(),
    );
    if shard == 0 {
        shared
            .sizer_restricted
            .store(sizer.restricted(), Ordering::Release);
    }
    // the cost model the sizer predicted from changes when the engine
    // re-plans; re-derive the admissible set when that counter moves
    let mut seen_replans = model.replans();
    let mut batches_run = 0u64;
    // timing of the steal that fed the next formed batch (count, secs)
    let mut pending_steal: Option<(usize, f64)> = None;
    loop {
        // heartbeat every iteration: idle wakes are bounded by
        // IDLE_POLL, so only a wedged `run_batch` (or a dead thread)
        // lets this stamp age past the watchdog's stall threshold
        shared.stats[shard].beat(shared.epoch);
        let shutting = shared.shutdown.load(Ordering::Acquire);
        let now = Instant::now();
        // 1. form from the own queue (forced flush while draining)
        let t_form = Instant::now();
        if let Some(formed) = shared.queues[shard].try_form(
            sizer.buckets(),
            row_elems,
            shared.max_wait,
            now,
            shutting,
        ) {
            let assemble_s = t_form.elapsed().as_secs_f64();
            run_batch(
                &shared,
                shard,
                model.as_mut(),
                formed,
                out_elems,
                assemble_s,
                pending_steal.take(),
            );
            batches_run += 1;
            if batches_run % ENGINE_PUBLISH_EVERY == 0 {
                publish_engine(&shared, shard, model.as_ref());
            }
            let replans = model.replans();
            if replans != seen_replans {
                seen_replans = replans;
                sizer = BatchSizer::for_model(
                    model.buckets(),
                    shared.slo,
                    shared.predictor.as_ref(),
                );
                if shard == 0 {
                    shared
                        .sizer_restricted
                        .store(sizer.restricted(), Ordering::Release);
                }
            }
            continue;
        }
        // 2. nothing formable at home: steal the deepest sibling's
        //    oldest requests (up to one admissible batch's worth).
        //    During shutdown each shard drains only its own queue.
        if !shutting {
            let t_steal = Instant::now();
            if let Some(n) = steal_from_sibling(&shared, shard, &sizer) {
                shared.stats[shard].steals.fetch_add(1, Ordering::Relaxed);
                pending_steal = Some((n, t_steal.elapsed().as_secs_f64()));
                continue; // the stolen work is now formable at home
            }
        }
        if shutting {
            // own queue fully drained (forced flush forms any tail)
            publish_engine(&shared, shard, model.as_ref());
            shared.stats[shard].exited.store(true, Ordering::Release);
            return;
        }
        // 3. sleep until the flush deadline / a submit's wake, capped
        //    by the idle poll (which also bounds steal-scan latency)
        let wait = shared.queues[shard]
            .time_until_flush(shared.max_wait, Instant::now())
            .unwrap_or(IDLE_POLL)
            .min(IDLE_POLL)
            .max(Duration::from_micros(100));
        let guard = shared.wake.lock().unwrap();
        // no lost wakeup: submit notifies under this lock after its
        // push, so anything that arrived since our scan is visible to
        // this re-probe, or its notify lands after we start waiting
        if shared.has_work(shard, sizer.min_bucket()) {
            drop(guard);
            continue;
        }
        let _ = shared.cv.wait_timeout(guard, wait).unwrap();
    }
}

/// Move up to one batch's worth of the deepest sibling's oldest
/// requests into `shard`'s queue; returns how many migrated.  Only
/// called when `shard` cannot form a batch, so a successful steal is
/// immediately consumed (no ping-pong: the minimum steal is a formable
/// bucket's worth or the victim's whole backlog).
fn steal_from_sibling(
    shared: &ModelShared,
    shard: usize,
    sizer: &BatchSizer,
) -> Option<usize> {
    let Some((victim, depth)) = shared
        .queues
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != shard)
        .map(|(i, q)| (i, q.depth()))
        .max_by_key(|&(_, d)| d)
    else {
        return None; // single shard: nobody to steal from
    };
    if depth < sizer.min_bucket() {
        return None;
    }
    let stolen = shared.queues[victim].pop_front_n(sizer.max_bucket().min(depth));
    if stolen.is_empty() {
        return None; // raced another thief
    }
    let n = stolen.len();
    for mut r in stolen {
        r.steals += 1; // the request migrated with its waiter
        shared.queues[shard].push(r);
    }
    Some(n)
}

/// Execute one formed batch and answer its waiters.  `assemble_s`
/// times the batch formation (pop + copy + pad) that produced
/// `formed`; `steal` carries the count/duration of the sibling steal
/// that fed it, when there was one.
fn run_batch(
    shared: &ModelShared,
    shard: usize,
    model: &mut dyn BatchModel,
    formed: Formed,
    out_elems: usize,
    assemble_s: f64,
    steal: Option<(usize, f64)>,
) {
    let Formed { reqs, data, padded, oldest_wait } = formed;
    let formed_at = Instant::now();
    let logits = model.run_batch(&data, padded).expect("fleet model run");
    let execute_s = formed_at.elapsed().as_secs_f64();
    let done = Instant::now();
    let lats: Vec<f64> = reqs
        .iter()
        .map(|r| done.duration_since(r.enqueued).as_secs_f64())
        .collect();
    shared.metrics.record_batch(reqs.len(), padded, &lats);
    // span chain: Queue, [Steal], Assemble, Execute, then the model's
    // per-layer spans (Execute *wraps* the layers — informational, not
    // additive; same for Steal, contained in the queue wait)
    let mut spans = Vec::with_capacity(4 + 4);
    spans.push(Span::queue(oldest_wait.as_secs_f64()));
    if let Some((n, secs)) = steal {
        spans.push(Span::steal(format!("{n} reqs migrated"), secs));
    }
    spans.push(Span::assemble(assemble_s, (data.len() * 4) as u64));
    spans.push(Span::execute(execute_s, (data.len() * 4) as u64));
    spans.extend(model.layer_spans());
    shared.metrics.traces().push(BatchTrace {
        seq: shared.metrics.batches(),
        ids: reqs.iter().map(|r| r.id).collect(),
        spans,
    });
    if let Some(slo) = shared.slo {
        let d = slo.p99_deadline.as_secs_f64();
        for &l in &lats {
            let hit = l <= d;
            if hit {
                shared.slo_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                shared.slo_misses.fetch_add(1, Ordering::Relaxed);
            }
            shared.metrics.record_slo(hit);
        }
    }
    let st = &shared.stats[shard];
    st.requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
    let batch_seq = st.batches.fetch_add(1, Ordering::Relaxed) + 1;
    if let Some(tw) = &shared.trace {
        for (row, r) in reqs.iter().enumerate() {
            tw.observe(&RequestTrace {
                model: shared.name.clone(),
                req: r.id,
                shard,
                batch_seq,
                rows: reqs.len(),
                padded,
                queue_s: formed_at.duration_since(r.enqueued).as_secs_f64(),
                steals: r.steals,
                assemble_s,
                execute_s,
                e2e_s: lats[row],
            });
        }
    }
    for (row, req) in reqs.into_iter().enumerate() {
        let l = logits[row * out_elems..(row + 1) * out_elems].to_vec();
        let argmax = l
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        // a receiver the client dropped is fine — send errors ignored
        let _ = req.tx.send(Response {
            id: req.id,
            logits: l,
            argmax,
            latency: Duration::from_secs_f64(lats[row]),
        });
    }
}

/// Refresh this shard's engine-side snapshot slot (None for models
/// without engine telemetry, e.g. mocks).
fn publish_engine(shared: &ModelShared, shard: usize, model: &dyn BatchModel) {
    *shared.stats[shard].engine.lock().unwrap() = model.obs_snapshot();
}

/// The watchdog's probe: classify every model's shards from liveness
/// atomics, queue probes, and the windowed SLO miss-rate.  Runs on the
/// watchdog thread — atomic loads and depth/front peeks only.
fn probe_fleet(
    models: &[(String, Arc<ModelShared>)],
    cfg: &WatchdogConfig,
) -> HealthReport {
    let now = Instant::now();
    let out = models
        .iter()
        .map(|(name, m)| {
            // model-level signal: windowed (shortest-window) miss-rate,
            // only meaningful when an SLO is configured
            let miss_rate = if m.slo.is_some() {
                m.metrics
                    .window_stats()
                    .first()
                    .map(|w| w.slo_miss_rate())
            } else {
                None
            };
            let shards = m
                .stats
                .iter()
                .enumerate()
                .map(|(i, st)| {
                    let heartbeat_age = st.heartbeat_age(m.epoch, now);
                    let probe = ShardProbe {
                        started: st.started.load(Ordering::Acquire),
                        exited: st.exited.load(Ordering::Acquire),
                        heartbeat_age,
                        queue_depth: m.queues[i].depth() as u64,
                        oldest_queue_age: m.queues[i].oldest_age(now),
                    };
                    ShardHealth {
                        shard: i,
                        state: classify(&probe, miss_rate, cfg),
                        heartbeat_age_s: heartbeat_age
                            .map(|d| d.as_secs_f64())
                            .unwrap_or(0.0),
                        queue_depth: probe.queue_depth,
                    }
                })
                .collect();
            ModelHealth { model: name.clone(), shards }
        })
        .collect();
    HealthReport { models: out }
}

/// Merge the shard replicas' engine-side snapshots onto the fleet
/// snapshot.  Pure throughput counters and per-layer / per-edge /
/// per-scheme attribution *sum* across replicas (each replica owns
/// private executor counters); identity fields (a layer's tag/scheme)
/// come from the replica that called that layer the most; drift ratios
/// are sample-weighted means; plan-cache counters — cumulative on the
/// one cache the replicas share — take the freshest (largest) view.
fn graft_merged_engines(snap: &mut Snapshot, engines: &[Snapshot]) {
    snap.engine_rows = engines.iter().map(|e| e.engine_rows).sum();
    snap.engine_busy_s = engines.iter().map(|e| e.engine_busy_s).sum();
    snap.replans = engines.iter().map(|e| e.replans).sum();
    snap.plan_cache_hits =
        engines.iter().map(|e| e.plan_cache_hits).max().unwrap_or(0);
    snap.plan_cache_misses =
        engines.iter().map(|e| e.plan_cache_misses).max().unwrap_or(0);

    // (merged attribution, best single-replica call count for identity)
    let mut layers: Vec<(LayerAttr, u64)> = Vec::new();
    for e in engines {
        for l in &e.layers {
            match layers.iter_mut().find(|(x, _)| x.index == l.index) {
                Some((x, best)) => {
                    x.calls += l.calls;
                    x.secs += l.secs;
                    x.predicted_s += l.predicted_s;
                    if l.calls > *best {
                        *best = l.calls;
                        x.tag = l.tag.clone();
                        x.scheme = l.scheme.clone();
                    }
                }
                None => layers.push((l.clone(), l.calls)),
            }
        }
    }
    layers.sort_by_key(|(x, _)| x.index);
    snap.layers = layers.into_iter().map(|(x, _)| x).collect();

    let mut edges: Vec<RepackEdge> = Vec::new();
    for e in engines {
        for r in &e.repack_edges {
            match edges
                .iter_mut()
                .find(|x| x.layer == r.layer && x.src == r.src && x.dst == r.dst)
            {
                Some(x) => {
                    x.ops += r.ops;
                    x.bytes += r.bytes;
                    x.secs += r.secs;
                }
                None => edges.push(r.clone()),
            }
        }
    }
    edges.sort_by(|a, b| {
        (a.layer, &a.src, &a.dst).cmp(&(b.layer, &b.src, &b.dst))
    });
    snap.repack_edges = edges;

    let mut repacks: Vec<(String, u64, u64)> = Vec::new();
    for e in engines {
        for (scheme, ops, bytes) in &e.repacks_by_scheme {
            match repacks.iter_mut().find(|(s, _, _)| s == scheme) {
                Some((_, o, b)) => {
                    *o += ops;
                    *b += bytes;
                }
                None => repacks.push((scheme.clone(), *ops, *bytes)),
            }
        }
    }
    repacks.sort_by(|a, b| a.0.cmp(&b.0));
    snap.repacks_by_scheme = repacks;

    let mut drift: Vec<(String, f64, u64)> = Vec::new();
    for e in engines {
        for (scheme, ratio, samples) in &e.cost_drift {
            match drift.iter_mut().find(|(s, _, _)| s == scheme) {
                Some((_, r, n)) => {
                    let total = *n + *samples;
                    if total > 0 {
                        *r = (*r * *n as f64 + *ratio * *samples as f64)
                            / total as f64;
                    }
                    *n = total;
                }
                None => drift.push((scheme.clone(), *ratio, *samples)),
            }
        }
    }
    drift.sort_by(|a, b| a.0.cmp(&b.0));
    snap.cost_drift = drift;
}

impl ScrapeSource for Fleet {
    /// Name-sorted per-model snapshots — `/metrics`, `/snapshot.json`
    /// and `/healthz` all render straight off this.
    fn snapshots(&self) -> Vec<(String, Snapshot)> {
        self.model_names()
            .into_iter()
            .map(|name| {
                let snap = self.snapshot(&name).expect("registered");
                (name, snap)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::MockModel;

    fn mock_factory(
        delay: Duration,
    ) -> impl Fn() -> Result<Box<dyn BatchModel>> + Send + Sync + Clone + 'static {
        move || {
            Ok(Box::new(MockModel { row_elems: 4, out_elems: 3, delay })
                as Box<dyn BatchModel>)
        }
    }

    #[test]
    fn serves_and_answers_every_accepted_request() {
        let mut fleet = Fleet::new();
        fleet.register("m", FleetModelConfig::default(), mock_factory(Duration::ZERO));
        let rxs: Vec<_> = (0..100)
            .map(|i| fleet.submit("m", vec![i as f32; 4]).expect("admitted"))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(30)).expect("answered");
            assert_eq!(r.logits[0], (i * 4) as f32, "request {i} got its own answer");
        }
        assert_eq!(fleet.metrics("m").unwrap().completed(), 100);
        assert_eq!(fleet.sheds("m"), Some(0));
    }

    #[test]
    fn unknown_and_shutdown_are_typed() {
        let mut fleet = Fleet::new();
        fleet.register("m", FleetModelConfig::default(), mock_factory(Duration::ZERO));
        match fleet.submit("nope", vec![]) {
            Err(FleetError::Route(RouteError::UnknownModel { requested, registered })) => {
                assert_eq!(requested, "nope");
                assert_eq!(registered, vec!["m".to_string()]);
            }
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        fleet.begin_shutdown();
        match fleet.submit("m", vec![0.0; 4]) {
            Err(FleetError::Route(RouteError::Shutdown { model })) => {
                assert_eq!(model, "m");
            }
            other => panic!("expected Shutdown, got {other:?}"),
        }
    }

    #[test]
    fn shutdown_flushes_pending_waiters() {
        let mut fleet = Fleet::new();
        fleet.register("m", FleetModelConfig::default(), mock_factory(Duration::ZERO));
        // 3 stragglers (below the smallest bucket): only the shutdown
        // drain's forced flush can answer them
        let rxs: Vec<_> = (0..3)
            .map(|i| fleet.submit("m", vec![i as f32; 4]).unwrap())
            .collect();
        fleet.shutdown();
        for rx in rxs {
            rx.recv().expect("flushed on shutdown, not leaked");
        }
    }

    #[test]
    fn idle_shard_steals_from_a_loaded_sibling() {
        let mut fleet = Fleet::new();
        fleet.register(
            "m",
            FleetModelConfig { shards: 2, ..Default::default() },
            // slow batches so the loaded shard stays loaded while the
            // idle one wakes up
            mock_factory(Duration::from_millis(20)),
        );
        // bypass round-robin dispatch: pile every request onto shard 0
        let shared = Arc::clone(&fleet.models["m"]);
        let rxs: Vec<_> = (0..64)
            .map(|i| {
                let (tx, rx) = channel();
                shared.queues[0].push(FleetReq {
                    id: i,
                    input: vec![i as f32; 4],
                    enqueued: Instant::now(),
                    steals: 0,
                    tx,
                });
                rx
            })
            .collect();
        shared.notify();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).expect("answered");
        }
        assert!(
            fleet.steals("m").unwrap() >= 1,
            "shard 1 must have stolen from shard 0's 64-deep queue"
        );
        // both shards did real work
        let snap = fleet.snapshot("m").unwrap();
        assert_eq!(snap.shards.len(), 2);
        assert!(snap.shards.iter().all(|s| s.requests > 0), "{:?}", snap.shards);
        assert_eq!(snap.steals, fleet.steals("m").unwrap());
        assert_eq!(snap.requests, 64);
    }

    #[test]
    fn depth_overload_sheds_synchronously() {
        let mut fleet = Fleet::new();
        fleet.register(
            "m",
            FleetModelConfig {
                shards: 1,
                admission: AdmissionConfig {
                    rate: None,
                    burst: 0.0,
                    max_queue_depth: 8,
                },
                ..Default::default()
            },
            // slow enough that the queue genuinely backs up
            mock_factory(Duration::from_millis(50)),
        );
        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for i in 0..200 {
            match fleet.submit("m", vec![i as f32; 4]) {
                Ok(rx) => accepted.push(rx),
                Err(FleetError::Overloaded(Overload::QueueFull)) => shed += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(shed > 0, "depth limit must shed under this burst");
        assert_eq!(fleet.sheds("m"), Some(shed));
        // zero lost waiters: every accepted request is answered
        for rx in accepted {
            rx.recv_timeout(Duration::from_secs(60)).expect("accepted => answered");
        }
    }

    #[test]
    fn low_priority_model_sheds_under_shared_host_pressure() {
        let mut fleet = Fleet::new();
        fleet.set_priority_pressure(4);
        // the critical model's worker exits (failed factory), so every
        // accepted request stays queued: deterministic backlog
        fleet.register(
            "critical",
            FleetModelConfig { shards: 1, ..Default::default() },
            || anyhow::bail!("no accelerator"),
        );
        fleet.register(
            "background",
            FleetModelConfig { shards: 1, priority: 1, ..Default::default() },
            mock_factory(Duration::ZERO),
        );
        // no pressure yet: background serves normally
        let rx = fleet.submit("background", vec![0.0; 4]).expect("no pressure");
        rx.recv_timeout(Duration::from_secs(30)).expect("answered");
        // build 4 queued requests of higher-priority backlog
        for i in 0..4 {
            fleet.submit("critical", vec![i as f32; 4]).expect("queued");
        }
        // background now yields the host...
        match fleet.submit("background", vec![0.0; 4]) {
            Err(FleetError::Overloaded(Overload::LowPriority)) => {}
            other => panic!("expected LowPriority shed, got {other:?}"),
        }
        // ...while the critical model itself is untouched by priority
        // shedding (priority 0 never yields)
        fleet.submit("critical", vec![0.0; 4]).expect("priority 0 admitted");
        assert_eq!(fleet.priority_sheds("background"), Some(1));
        assert_eq!(fleet.sheds("background"), Some(1), "counted as a shed too");
        assert_eq!(fleet.priority_sheds("critical"), Some(0));
        let snap = fleet.snapshot("background").unwrap();
        assert_eq!(snap.priority_sheds, 1);
        assert_eq!(snap.sheds, 1);
    }

    /// A mock whose engine-side snapshot is synthetic per-replica
    /// attribution — exercises the cross-replica merge in
    /// `Fleet::snapshot` without a real engine.
    struct AttrMock {
        inner: MockModel,
        replica: usize,
    }

    impl BatchModel for AttrMock {
        fn run_batch(&mut self, data: &[f32], padded: usize) -> Result<Vec<f32>> {
            self.inner.run_batch(data, padded)
        }
        fn row_elems(&self) -> usize {
            self.inner.row_elems()
        }
        fn out_elems(&self) -> usize {
            self.inner.out_elems()
        }
        fn buckets(&self) -> Vec<usize> {
            self.inner.buckets()
        }
        fn obs_snapshot(&self) -> Option<Snapshot> {
            let mut s = Snapshot::default();
            if self.replica == 0 {
                s.engine_rows = 30;
                s.engine_busy_s = 0.3;
                s.plan_cache_hits = 5;
                s.plan_cache_misses = 2;
                s.replans = 1;
                s.layers = vec![LayerAttr {
                    index: 0,
                    tag: "1024FC".to_string(),
                    scheme: "FASTPATH".to_string(),
                    calls: 3,
                    secs: 0.3,
                    predicted_s: 0.2,
                }];
                s.cost_drift = vec![("FASTPATH".to_string(), 2.0, 2)];
                s.repacks_by_scheme = vec![("FASTPATH".to_string(), 1, 100)];
                s.repack_edges = vec![RepackEdge {
                    layer: 0,
                    src: "Row32".to_string(),
                    dst: "Blocked64".to_string(),
                    ops: 1,
                    bytes: 10,
                    secs: 1e-3,
                }];
            } else {
                s.engine_rows = 10;
                s.engine_busy_s = 0.1;
                s.plan_cache_hits = 6; // fresher view of the shared cache
                s.plan_cache_misses = 2;
                s.replans = 0;
                s.layers = vec![LayerAttr {
                    index: 0,
                    tag: "1024FC-alt".to_string(),
                    scheme: "SBNN-64".to_string(),
                    calls: 1,
                    secs: 0.1,
                    predicted_s: 0.1,
                }];
                s.cost_drift = vec![("FASTPATH".to_string(), 1.0, 2)];
                s.repacks_by_scheme = vec![("FASTPATH".to_string(), 2, 50)];
                s.repack_edges = vec![RepackEdge {
                    layer: 0,
                    src: "Row32".to_string(),
                    dst: "Blocked64".to_string(),
                    ops: 2,
                    bytes: 20,
                    secs: 2e-3,
                }];
            }
            Some(s)
        }
    }

    #[test]
    fn snapshot_merges_attribution_across_replicas() {
        let replica = Arc::new(AtomicUsize::new(0));
        let mut fleet = Fleet::new();
        let r = Arc::clone(&replica);
        fleet.register(
            "m",
            FleetModelConfig { shards: 2, ..Default::default() },
            move || {
                Ok(Box::new(AttrMock {
                    inner: MockModel {
                        row_elems: 4,
                        out_elems: 3,
                        delay: Duration::ZERO,
                    },
                    replica: r.fetch_add(1, Ordering::Relaxed),
                }) as Box<dyn BatchModel>)
            },
        );
        // drain + exit publishes each replica's engine snapshot
        fleet.begin_shutdown();
        let shared = Arc::clone(&fleet.models["m"]);
        let deadline = Instant::now() + Duration::from_secs(30);
        while shared
            .stats
            .iter()
            .any(|s| s.engine.lock().unwrap().is_none())
        {
            assert!(Instant::now() < deadline, "replicas never published");
            std::thread::sleep(Duration::from_millis(2));
        }
        let snap = fleet.snapshot("m").unwrap();
        // throughput counters summed across replicas
        assert_eq!(snap.engine_rows, 40);
        assert!((snap.engine_busy_s - 0.4).abs() < 1e-9);
        assert_eq!(snap.replans, 1);
        // shared plan cache: freshest (largest) counter view
        assert_eq!(snap.plan_cache_hits, 6);
        assert_eq!(snap.plan_cache_misses, 2);
        // per-layer attribution merged, not busiest-shard sampled:
        // calls/secs/predicted sum; identity from the most-called replica
        assert_eq!(snap.layers.len(), 1);
        let l = &snap.layers[0];
        assert_eq!(l.calls, 4);
        assert!((l.secs - 0.4).abs() < 1e-9);
        assert!((l.predicted_s - 0.3).abs() < 1e-9);
        assert_eq!(l.tag, "1024FC");
        assert_eq!(l.scheme, "FASTPATH");
        // drift: sample-weighted mean, samples summed
        assert_eq!(snap.cost_drift.len(), 1);
        let (ref scheme, ratio, n) = snap.cost_drift[0];
        assert_eq!(scheme, "FASTPATH");
        assert!((ratio - 1.5).abs() < 1e-9, "weighted (2.0*2 + 1.0*2)/4");
        assert_eq!(n, 4);
        // repack scheme totals and per-edge traffic summed
        assert_eq!(snap.repacks_by_scheme, vec![("FASTPATH".to_string(), 3, 150)]);
        assert_eq!(snap.repack_edges.len(), 1);
        assert_eq!(snap.repack_edges[0].ops, 3);
        assert_eq!(snap.repack_edges[0].bytes, 30);
    }

    #[test]
    fn watchdog_reports_health_and_flags_an_exited_worker() {
        let mut fleet = Fleet::new();
        fleet.register("ok", FleetModelConfig::default(), mock_factory(Duration::ZERO));
        fleet.register(
            "bad",
            FleetModelConfig { shards: 1, ..Default::default() },
            || anyhow::bail!("no accelerator"),
        );
        assert!(fleet.health_report().is_none(), "no watchdog yet");
        fleet.start_watchdog(WatchdogConfig {
            period: Duration::from_millis(5),
            // generous liveness thresholds: this test only drives the
            // exited-worker path, and CI boxes deschedule threads
            stall_after: Duration::from_secs(30),
            max_queue_age: Duration::from_secs(30),
            ..Default::default()
        });
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            assert!(Instant::now() < deadline, "watchdog never saw the exit");
            let Some(report) = fleet.health_report() else { continue };
            if report.models.len() == 2 && !report.all_up() {
                let bad = &report.models[0]; // name-sorted: bad, ok
                assert_eq!(bad.model, "bad");
                assert_eq!(bad.shards[0].state.name(), "stalled");
                assert_eq!(bad.shards[0].state.reason(), "worker exited");
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // the health block lands on the per-model snapshot + scrape feed
        let snap = fleet.snapshot("bad").unwrap();
        assert_eq!(snap.health.len(), 1);
        assert_eq!(snap.health[0].state, "stalled");
        assert!(!snap.health[0].is_up());
        let snaps = fleet.snapshots();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].0, "bad");
        // shutdown stops the watchdog before workers exit: no
        // false-stall report survives
        fleet.begin_shutdown();
        assert!(fleet.health_report().is_none());
    }

    /// Delegating mock with an externally-driven re-plan counter — the
    /// satellite hook: a worker must re-derive its SLO-admissible
    /// buckets when the model re-plans.
    struct ReplanMock {
        inner: MockModel,
        replans: Arc<AtomicU64>,
    }

    impl BatchModel for ReplanMock {
        fn run_batch(&mut self, data: &[f32], padded: usize) -> Result<Vec<f32>> {
            self.inner.run_batch(data, padded)
        }
        fn row_elems(&self) -> usize {
            self.inner.row_elems()
        }
        fn out_elems(&self) -> usize {
            self.inner.out_elems()
        }
        fn buckets(&self) -> Vec<usize> {
            self.inner.buckets()
        }
        fn replans(&self) -> u64 {
            self.replans.load(Ordering::Acquire)
        }
    }

    #[test]
    fn sizer_rederives_admissible_buckets_after_a_replan() {
        // predicted cost per row, swappable at runtime (nanoseconds)
        let cost_ns = Arc::new(AtomicU64::new(1_000)); // 8 rows -> 8us: all fit
        let replans = Arc::new(AtomicU64::new(0));
        let pred_cost = Arc::clone(&cost_ns);
        let predictor: BatchSecsPredictor = Arc::new(move |b| {
            Some(pred_cost.load(Ordering::Acquire) as f64 * 1e-9 * b as f64)
        });
        let mut fleet = Fleet::new();
        let rp = Arc::clone(&replans);
        fleet.register(
            "m",
            FleetModelConfig {
                shards: 1,
                slo: Some(SloConfig { p99_deadline: Duration::from_millis(1) }),
                predictor: Some(predictor),
                ..Default::default()
            },
            move || {
                Ok(Box::new(ReplanMock {
                    inner: MockModel {
                        row_elems: 4,
                        out_elems: 3,
                        delay: Duration::ZERO,
                    },
                    replans: Arc::clone(&rp),
                }) as Box<dyn BatchModel>)
            },
        );
        let deadline = Instant::now() + Duration::from_secs(30);
        while fleet.slo_restricted("m") != Some(false) {
            assert!(Instant::now() < deadline, "worker never built its sizer");
            std::thread::sleep(Duration::from_millis(2));
        }
        // the cost model drifts 100x (as a live re-plan would discover):
        // t(8)=0.8ms fits the 1ms deadline, t(32)=3.2ms no longer does
        cost_ns.store(100_000, Ordering::Release);
        replans.store(1, Ordering::Release);
        // the worker re-checks after its next batch
        let rxs: Vec<_> = (0..8)
            .map(|i| fleet.submit("m", vec![i as f32; 4]).expect("admitted"))
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).expect("answered");
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        while fleet.slo_restricted("m") != Some(true) {
            assert!(
                Instant::now() < deadline,
                "sizer never re-derived after the re-plan"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}
