//! Regeneration of every table and figure in the paper's evaluation
//! (§4 characterization + §7).  Shared by `tcbnn figures`, the bench
//! binaries, and EXPERIMENTS.md.
//!
//! Each function returns a `Table` whose rows mirror the paper's plot
//! series / table rows; `write_all` dumps the complete set as CSV under
//! `results/`.

use crate::coordinator::benn::{benn_cost, Ensemble};
use crate::coordinator::comm::{IB_MPI, PCIE_NCCL};
use crate::kernels::bconv::{self, BconvProblem};
use crate::kernels::bmm::{self, BmmProblem};
use crate::kernels::IoMode;
use crate::nn::model::{all_models, imagenet_resnet, imagenet_resnet18};
use crate::nn::{model_cost, ResidualMode, Scheme};
use crate::sim::{tensorcore, wmma, Engine, GpuModel, MemSpace, RTX2080, RTX2080TI};
use crate::util::table::Table;

fn ms(s: f64) -> String {
    format!("{:.3}", s * 1e3)
}

fn fps(v: f64) -> String {
    format!("{:.3e}", v)
}

/// Figs 2–5: load_matrix_sync latency vs ldm, global + shared.
pub fn fig_load_latency(gpu: &GpuModel) -> Table {
    let mut t = Table::new(
        &format!("Figs 2-5: load_matrix_sync latency vs ldm ({})", gpu.name),
        &["ldm", "global_cycles", "shared_cycles"],
    );
    for i in 1..=14 {
        let ldm = 128 * i;
        t.row(&[
            ldm.to_string(),
            format!("{:.0}", wmma::load_latency(gpu, ldm, MemSpace::Global)),
            format!("{:.0}", wmma::load_latency(gpu, ldm, MemSpace::Shared)),
        ]);
    }
    t
}

/// Figs 6–9: store_matrix_sync latency vs ldm.
pub fn fig_store_latency(gpu: &GpuModel) -> Table {
    let mut t = Table::new(
        &format!("Figs 6-9: store_matrix_sync latency vs ldm ({})", gpu.name),
        &["ldm", "global_cycles", "shared_cycles"],
    );
    for i in 1..=14 {
        let ldm = 8 * i;
        t.row(&[
            ldm.to_string(),
            format!("{:.0}", wmma::store_latency(gpu, ldm, MemSpace::Global)),
            format!("{:.0}", wmma::store_latency(gpu, ldm, MemSpace::Shared)),
        ]);
    }
    t
}

/// Figs 10–13: bmma_sync total latency vs number of ops.
pub fn fig_bmma_pipeline(gpu: &GpuModel) -> Table {
    let mut t = Table::new(
        &format!("Figs 10-13: bmma_sync latency vs #ops ({})", gpu.name),
        &["n_ops", "same_accumulator_cycles", "diff_accumulator_cycles"],
    );
    for n in 1..=16 {
        t.row(&[
            n.to_string(),
            format!("{:.0}", tensorcore::bmma_latency(gpu, n, true)),
            format!("{:.0}", tensorcore::bmma_latency(gpu, n, false)),
        ]);
    }
    t
}

/// Figs 16–19: BMM TOPS vs matrix size for every Table-3/4 scheme.
pub fn fig_bmm(gpu: &GpuModel, mode: IoMode) -> Table {
    let engine = Engine::new(gpu);
    let schemes = bmm::all_schemes();
    let mut header = vec!["n".to_string()];
    header.extend(schemes.iter().map(|s| s.name().to_string()));
    let mut t = Table::new(
        &format!(
            "Figs 16-19: {} BMM TOPS ({})",
            if mode == IoMode::General { "general" } else { "BNN-specific" },
            gpu.name
        ),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut n = 128;
    while n <= 16384 {
        let mut row = vec![n.to_string()];
        let p = BmmProblem::square(n);
        for s in &schemes {
            if s.supports(p, mode) {
                row.push(format!("{:.2}", bmm::simulate_tops(&engine, s.as_ref(), p, mode)));
            } else {
                row.push("-".to_string());
            }
        }
        t.row(&row);
        n *= 2;
    }
    t
}

/// Figs 20–23: BConv TOPS over the C=O sweep.
pub fn fig_bconv(gpu: &GpuModel, mode: IoMode) -> Table {
    let engine = Engine::new(gpu);
    let schemes = bconv::all_schemes();
    let mut header = vec!["c=o".to_string()];
    header.extend(schemes.iter().map(|s| s.name().to_string()));
    let mut t = Table::new(
        &format!(
            "Figs 20-23: {} BConv TOPS ({})",
            if mode == IoMode::General { "general" } else { "BNN-specific" },
            gpu.name
        ),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for c in (128..=2048).step_by(128) {
        let p = BconvProblem::paper_sweep(c, c);
        let mut row = vec![c.to_string()];
        for s in &schemes {
            if s.supports(p, mode) {
                row.push(format!(
                    "{:.2}",
                    bconv::simulate_tops(&engine, s.as_ref(), p, mode)
                ));
            } else {
                row.push("-".to_string());
            }
        }
        t.row(&row);
    }
    t
}

/// Table 5: the evaluation models.
pub fn table5() -> Table {
    let mut t = Table::new(
        "Table 5: evaluation models",
        &["model", "dataset", "conv_layers", "fc_layers", "weight_MB", "classes"],
    );
    for m in all_models() {
        t.row(&[
            m.name.to_string(),
            m.dataset.to_string(),
            m.conv_layers().to_string(),
            m.fc_layers().to_string(),
            format!("{:.2}", m.weight_bits() as f64 / 8e6),
            m.classes.to_string(),
        ]);
    }
    t
}

/// Tables 6–7: 8-image latency + throughput per scheme and model.
pub fn tables_6_7(gpu: &GpuModel) -> Table {
    let title = if gpu.name == "RTX2080Ti" {
        "Table 7: inference on RTX2080Ti"
    } else {
        "Table 6: inference on RTX2080"
    };
    let mut header = vec!["scheme".to_string()];
    for m in all_models() {
        header.push(format!("{}_lat8_ms", m.name));
        header.push(format!("{}_fps", m.name));
    }
    let mut t = Table::new(title, &header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    // paper rows only: FASTPATH and SIMD are host backends, not GPU
    // schemes, so they have no place in a Tables-6/7 reproduction
    for s in Scheme::all().into_iter().filter(|s| !s.is_host()) {
        let mut row = vec![s.name().to_string()];
        for m in all_models() {
            let lat = model_cost(&m, 8, gpu, s, ResidualMode::Full, true);
            let tput_batch = if m.dataset == "ImageNet" { 512 } else { 1024 };
            let tp = model_cost(&m, tput_batch, gpu, s, ResidualMode::Full, true);
            row.push(ms(lat.total_secs));
            row.push(fps(tp.throughput_fps()));
        }
        t.row(&row);
    }
    t
}

/// Tables 8–9: cross-platform comparison (paper rows as published
/// constants + our simulated BTC rows).
pub fn tables_8_9(gpu: &GpuModel) -> Table {
    let mut t = Table::new(
        "Tables 8-9: cross-platform (paper-published rows + our BTC)",
        &["system", "platform", "network", "raw_latency_us", "throughput_img_s"],
    );
    // published rows (Table 8: AlexNet; Table 9: VGG-16)
    for (sys, plat, net, lat_us, tput) in [
        ("RebNet [72]", "Xilinx VCU108 FPGA", "AlexNet", 1902.0, 521.0),
        ("FP-BNN [23]", "Intel Stratix-V FPGA", "AlexNet", 1160.0, 862.0),
        ("O3BNN [25]", "Xilinx ZC706 FPGA", "AlexNet", 774.0, 1292.0),
        ("SBNN [26]", "Tesla V100 GPU", "AlexNet", 979.0, 4400.0),
        ("BitFlow [40]", "GTX1080 GPU", "VGG-16", 12870.0, 78.0),
        ("BitFlow [40]", "Intel i7-7700HQ", "VGG-16", 16100.0, 62.0),
        ("BitFlow [40]", "Xeon-Phi 7210", "VGG-16", 11820.0, 85.0),
        ("FBNA", "Xilinx ZC706 FPGA", "VGG-16", f64::NAN, 178.0),
        ("SBNN [26]", "Tesla V100 GPU", "VGG-16", f64::NAN, 312.0),
    ] {
        t.row(&[
            sys.to_string(),
            plat.to_string(),
            net.to_string(),
            if lat_us.is_nan() { "-".into() } else { format!("{lat_us:.0}") },
            format!("{tput:.0}"),
        ]);
    }
    // our simulated rows (single-image latency = batch-8 latency / 8
    // amortized, like the paper's "raw latency" protocol)
    for m in [crate::nn::model::imagenet_alexnet(), crate::nn::model::imagenet_vgg16()] {
        let lat = model_cost(&m, 8, gpu, Scheme::BtcFmt, ResidualMode::Full, true);
        let tp = model_cost(&m, 512, gpu, Scheme::BtcFmt, ResidualMode::Full, true);
        t.row(&[
            "BTC (this repro, simulated)".to_string(),
            gpu.name.to_string(),
            m.name.to_string(),
            format!("{:.0}", lat.total_secs / 8.0 * 1e6),
            format!("{:.0}", tp.throughput_fps()),
        ]);
    }
    t
}

/// Fig 24: per-layer latency breakdown (share of total).
pub fn fig24_breakdown(gpu: &GpuModel) -> Table {
    let mut t = Table::new(
        "Fig 24: per-layer latency breakdown (BTC-FMT, batch 8)",
        &["model", "layer", "ms", "share_pct"],
    );
    for m in all_models() {
        let c = model_cost(&m, 8, gpu, Scheme::BtcFmt, ResidualMode::Full, true);
        for l in &c.layers {
            t.row(&[
                m.name.to_string(),
                l.tag.clone(),
                ms(l.secs),
                format!("{:.1}", l.secs / c.total_secs * 100.0),
            ]);
        }
    }
    t
}

/// Table 10: layer-wise synchronization overhead.
pub fn table10_sync(gpu: &GpuModel) -> Table {
    let mut t = Table::new(
        "Table 10: layer-sync overhead (BTC-FMT, batch 8)",
        &["model", "with_sync_ms", "no_sync_ms", "overhead_pct"],
    );
    for m in all_models() {
        let with = model_cost(&m, 8, gpu, Scheme::BtcFmt, ResidualMode::Full, true);
        let without = model_cost(&m, 8, gpu, Scheme::BtcFmt, ResidualMode::Full, false);
        t.row(&[
            m.name.to_string(),
            ms(with.total_secs),
            ms(without.total_secs),
            format!(
                "{:.1}",
                (with.total_secs - without.total_secs) / with.total_secs * 100.0
            ),
        ]);
    }
    t
}

/// Fig 25: normalized throughput vs batch size.
pub fn fig25_batch(gpu: &GpuModel) -> Table {
    let mut t = Table::new(
        "Fig 25: throughput vs batch (normalized to the table batch)",
        &["model", "batch", "fps", "normalized"],
    );
    for m in all_models() {
        let norm_batch = if m.dataset == "ImageNet" { 512 } else { 1024 };
        let base = model_cost(&m, norm_batch, gpu, Scheme::BtcFmt, ResidualMode::Full, true)
            .throughput_fps();
        for b in [8usize, 16, 32, 64, 128, 256, 512, 1024] {
            let f = model_cost(&m, b, gpu, Scheme::BtcFmt, ResidualMode::Full, true)
                .throughput_fps();
            t.row(&[
                m.name.to_string(),
                b.to_string(),
                format!("{:.0}", f),
                format!("{:.3}", f / base),
            ]);
        }
    }
    t
}

/// Fig 26: ResNet shortcut overhead scenarios.
pub fn fig26_shortcut(gpu: &GpuModel) -> Table {
    let mut t = Table::new(
        "Fig 26: residual handling (BTC-FMT, batch 8)",
        &["model", "scenario", "latency_ms", "fps_batch512"],
    );
    for m in [crate::nn::model::cifar_resnet14(), imagenet_resnet18()] {
        for (name, mode) in [
            ("with-residual", ResidualMode::Full),
            ("save-only", ResidualMode::SaveOnly),
            ("fetch-only", ResidualMode::FetchOnly),
            ("no-residual", ResidualMode::None),
        ] {
            let lat = model_cost(&m, 8, gpu, Scheme::BtcFmt, mode, true);
            let tp = model_cost(&m, 512, gpu, Scheme::BtcFmt, mode, true);
            t.row(&[
                m.name.to_string(),
                name.to_string(),
                ms(lat.total_secs),
                format!("{:.0}", tp.throughput_fps()),
            ]);
        }
    }
    t
}

/// Table 11: ResNet depth scaling.
pub fn table11_depth(gpu: &GpuModel) -> Table {
    let mut t = Table::new(
        "Table 11: 8-img latency vs ResNet depth",
        &["depth", "BTC_ms", "BTC-FMT_ms"],
    );
    for d in [18usize, 50, 101, 152] {
        let m = imagenet_resnet(d);
        let btc = model_cost(&m, 8, gpu, Scheme::Btc, ResidualMode::Full, true);
        let fmt = model_cost(&m, 8, gpu, Scheme::BtcFmt, ResidualMode::Full, true);
        t.row(&[d.to_string(), ms(btc.total_secs), ms(fmt.total_secs)]);
    }
    t
}

/// Figs 27–28: BENN scaling-up (PCIe/NCCL) and scaling-out (IB/MPI).
pub fn figs_27_28(gpu: &GpuModel) -> Table {
    let mut t = Table::new(
        "Figs 27-28: BENN latency breakdown (ResNet-18, batch 128)",
        &["fabric", "ensemble", "gpus", "compute_ms", "comm_ms", "total_ms"],
    );
    let m = imagenet_resnet18();
    for (fabric, fname) in [(PCIE_NCCL, "scale-up"), (IB_MPI, "scale-out")] {
        for e in [Ensemble::HardBagging, Ensemble::SoftBagging, Ensemble::Boosting] {
            for n in 1..=8usize {
                let c = benn_cost(&m, 128, gpu, Scheme::BtcFmt, n, fabric, e);
                t.row(&[
                    format!("{fname}({})", fabric.name),
                    e.name().to_string(),
                    n.to_string(),
                    ms(c.compute_s),
                    ms(c.comm_s),
                    ms(c.total_s()),
                ]);
            }
        }
    }
    t
}

/// Generate every table/figure, print, and write CSVs under `dir`.
pub fn write_all(dir: &str) -> std::io::Result<Vec<String>> {
    let mut paths = Vec::new();
    let mut emit = |name: &str, t: Table| -> std::io::Result<()> {
        println!("{}", t.render());
        paths.push(t.write_csv(dir, name)?);
        Ok(())
    };
    for gpu in [&RTX2080TI, &RTX2080] {
        let tag = gpu.name.to_lowercase();
        emit(&format!("fig02_05_load_{tag}"), fig_load_latency(gpu))?;
        emit(&format!("fig06_09_store_{tag}"), fig_store_latency(gpu))?;
        emit(&format!("fig10_13_bmma_{tag}"), fig_bmma_pipeline(gpu))?;
        emit(&format!("fig16_18_bmm_general_{tag}"), fig_bmm(gpu, IoMode::General))?;
        emit(
            &format!("fig17_19_bmm_specific_{tag}"),
            fig_bmm(gpu, IoMode::BnnSpecific),
        )?;
        emit(
            &format!("fig20_22_bconv_general_{tag}"),
            fig_bconv(gpu, IoMode::General),
        )?;
        emit(
            &format!("fig21_23_bconv_specific_{tag}"),
            fig_bconv(gpu, IoMode::BnnSpecific),
        )?;
        emit(&format!("table6_7_models_{tag}"), tables_6_7(gpu))?;
    }
    emit("table5_models", table5())?;
    emit("table8_9_crossplatform", tables_8_9(&RTX2080TI))?;
    emit("fig24_breakdown", fig24_breakdown(&RTX2080))?;
    emit("table10_sync", table10_sync(&RTX2080))?;
    emit("fig25_batch", fig25_batch(&RTX2080))?;
    emit("fig26_shortcut", fig26_shortcut(&RTX2080))?;
    emit("table11_depth", table11_depth(&RTX2080))?;
    emit("fig27_28_benn", figs_27_28(&RTX2080TI))?;
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_latency_table_has_minimum_at_128() {
        let t = fig_load_latency(&RTX2080TI);
        let cycles: Vec<f64> = t
            .rows
            .iter()
            .map(|r| r[1].parse::<f64>().unwrap())
            .collect();
        let min = cycles.iter().cloned().fold(f64::MAX, f64::min);
        assert_eq!(cycles[0], min, "ldm=128 is the global minimum");
    }

    #[test]
    fn bmm_table_bmmafmt_wins_at_4k() {
        let t = fig_bmm(&RTX2080TI, IoMode::BnnSpecific);
        // header: n, schemes...; find bmmafmt column and the 4096 row
        let col = 1 + bmm::all_schemes()
            .iter()
            .position(|s| s.name() == "bmmafmt")
            .unwrap();
        let row = t.rows.iter().find(|r| r[0] == "4096").unwrap();
        let fmt: f64 = row[col].parse().unwrap();
        for (i, cell) in row.iter().enumerate().skip(1) {
            if i == col || cell == "-" {
                continue;
            }
            let v: f64 = cell.parse().unwrap();
            assert!(fmt >= v, "bmmafmt {fmt} vs col {i} = {v}");
        }
    }

    #[test]
    fn tables_6_7_have_all_rows() {
        let t = tables_6_7(&RTX2080TI);
        assert_eq!(t.rows.len(), 6); // six schemes
        assert_eq!(t.rows[5][0], "BTC-FMT");
        assert_eq!(t.header.len(), 1 + 12); // 6 models x (lat, fps)
    }

    #[test]
    fn benn_table_shape() {
        let t = figs_27_28(&RTX2080TI);
        assert_eq!(t.rows.len(), 2 * 3 * 8);
    }
}
