//! Flat tensor blobs (`*.bin` + `*.meta`) written by train.py.
//!
//! Meta line format: `name dtype shape offset nbytes`.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::artifact::DType;

/// One named tensor inside a blob.
#[derive(Clone, Debug)]
pub struct BlobTensor {
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// A loaded weight/test-set blob.
#[derive(Clone, Debug)]
pub struct Blob {
    pub tensors: HashMap<String, BlobTensor>,
    pub data: Vec<u8>,
}

impl Blob {
    /// Load `base.bin` + `base.meta`.
    pub fn load(base: &str) -> Result<Blob> {
        let meta = std::fs::read_to_string(format!("{base}.meta"))
            .with_context(|| format!("reading {base}.meta"))?;
        let data = std::fs::read(format!("{base}.bin"))
            .with_context(|| format!("reading {base}.bin"))?;
        let mut tensors = HashMap::new();
        for line in meta.lines() {
            let t: Vec<&str> = line.split_whitespace().collect();
            if t.is_empty() {
                continue;
            }
            if t.len() != 5 {
                bail!("bad meta line: {line:?}");
            }
            let dims = t[2]
                .split('x')
                .map(|d| d.parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?;
            let tensor = BlobTensor {
                dtype: DType::parse(t[1])?,
                dims,
                offset: t[3].parse()?,
                nbytes: t[4].parse()?,
            };
            if tensor.offset + tensor.nbytes > data.len() {
                bail!("tensor {} overruns blob", t[0]);
            }
            tensors.insert(t[0].to_string(), tensor);
        }
        Ok(Blob { tensors, data })
    }

    pub fn get(&self, name: &str) -> Result<&BlobTensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor {name:?} not in blob"))
    }

    /// Raw little-endian bytes of a tensor.
    pub fn bytes(&self, name: &str) -> Result<&[u8]> {
        let t = self.get(name)?;
        Ok(&self.data[t.offset..t.offset + t.nbytes])
    }

    pub fn as_f32(&self, name: &str) -> Result<Vec<f32>> {
        let t = self.get(name)?;
        if t.dtype != DType::F32 {
            bail!("tensor {name:?} is not f32");
        }
        Ok(bytes_to_vec(self.bytes(name)?, f32::from_le_bytes))
    }

    pub fn as_u32(&self, name: &str) -> Result<Vec<u32>> {
        let t = self.get(name)?;
        if t.dtype != DType::U32 {
            bail!("tensor {name:?} is not u32");
        }
        Ok(bytes_to_vec(self.bytes(name)?, u32::from_le_bytes))
    }

    pub fn as_i32(&self, name: &str) -> Result<Vec<i32>> {
        let t = self.get(name)?;
        if t.dtype != DType::I32 {
            bail!("tensor {name:?} is not i32");
        }
        Ok(bytes_to_vec(self.bytes(name)?, i32::from_le_bytes))
    }
}

/// Builder for `*.bin` + `*.meta` blobs (the writer side of `Blob`,
/// used by the engine's weight persistence and by tests; train.py is
/// the other producer of this format).
#[derive(Default)]
pub struct BlobWriter {
    meta: String,
    data: Vec<u8>,
}

impl BlobWriter {
    pub fn new() -> BlobWriter {
        BlobWriter::default()
    }

    fn push_raw(&mut self, name: &str, dtype: &str, dims: &[usize], bytes: &[u8]) {
        assert!(!name.contains(char::is_whitespace), "tensor name {name:?}");
        let dims_s = if dims.is_empty() {
            "1".to_string()
        } else {
            dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
        };
        let offset = self.data.len();
        self.meta.push_str(&format!(
            "{name} {dtype} {dims_s} {offset} {}\n",
            bytes.len()
        ));
        self.data.extend_from_slice(bytes);
    }

    pub fn push_f32(&mut self, name: &str, dims: &[usize], xs: &[f32]) {
        assert_eq!(xs.len(), dims.iter().product::<usize>().max(1));
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        self.push_raw(name, "f32", dims, &bytes);
    }

    pub fn push_u32(&mut self, name: &str, dims: &[usize], xs: &[u32]) {
        assert_eq!(xs.len(), dims.iter().product::<usize>().max(1));
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        self.push_raw(name, "u32", dims, &bytes);
    }

    pub fn push_i32(&mut self, name: &str, dims: &[usize], xs: &[i32]) {
        assert_eq!(xs.len(), dims.iter().product::<usize>().max(1));
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        self.push_raw(name, "i32", dims, &bytes);
    }

    /// Write `base.bin` + `base.meta`.
    pub fn write(&self, base: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(base).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(format!("{base}.bin"), &self.data)?;
        std::fs::write(format!("{base}.meta"), &self.meta)
    }
}

fn bytes_to_vec<T, F: Fn([u8; 4]) -> T>(bytes: &[u8], conv: F) -> Vec<T> {
    bytes
        .chunks_exact(4)
        .map(|c| conv([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_temp_blob() -> String {
        let dir = std::env::temp_dir().join(format!("tcbnn_blob_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("test").to_str().unwrap().to_string();
        let f32s: Vec<f32> = vec![1.5, -2.0, 3.25];
        let u32s: Vec<u32> = vec![7, 0xFFFF_FFFF];
        let mut bin = std::fs::File::create(format!("{base}.bin")).unwrap();
        for x in &f32s {
            bin.write_all(&x.to_le_bytes()).unwrap();
        }
        for x in &u32s {
            bin.write_all(&x.to_le_bytes()).unwrap();
        }
        std::fs::write(
            format!("{base}.meta"),
            "a f32 3 0 12\nb u32 2 12 8\n",
        )
        .unwrap();
        base
    }

    #[test]
    fn roundtrip() {
        let base = write_temp_blob();
        let blob = Blob::load(&base).unwrap();
        assert_eq!(blob.as_f32("a").unwrap(), vec![1.5, -2.0, 3.25]);
        assert_eq!(blob.as_u32("b").unwrap(), vec![7, 0xFFFF_FFFF]);
        assert_eq!(blob.get("b").unwrap().dims, vec![2]);
        assert!(blob.as_f32("b").is_err()); // dtype mismatch
        assert!(blob.get("missing").is_err());
    }

    #[test]
    fn writer_reader_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("tcbnn_blobw_{}", std::process::id()));
        let base = dir.join("rt").to_str().unwrap().to_string();
        let mut w = BlobWriter::new();
        w.push_f32("a", &[2, 2], &[1.0, -2.0, 0.5, 4.0]);
        w.push_u32("b", &[3], &[1, 2, 0xDEAD_BEEF]);
        w.push_i32("c", &[1], &[-7]);
        w.write(&base).unwrap();
        let blob = Blob::load(&base).unwrap();
        assert_eq!(blob.as_f32("a").unwrap(), vec![1.0, -2.0, 0.5, 4.0]);
        assert_eq!(blob.as_u32("b").unwrap(), vec![1, 2, 0xDEAD_BEEF]);
        assert_eq!(blob.as_i32("c").unwrap(), vec![-7]);
        assert_eq!(blob.get("a").unwrap().dims, vec![2, 2]);
    }

    #[test]
    fn rejects_overrun() {
        let base = write_temp_blob();
        std::fs::write(format!("{base}.meta"), "a f32 100 0 400\n").unwrap();
        assert!(Blob::load(&base).is_err());
    }
}
