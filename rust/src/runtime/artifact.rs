//! Artifact manifest parsing.
//!
//! `artifacts/manifest.txt` is a line-oriented description written by
//! aot.py:
//!
//! ```text
//! artifact mlp_b8 mlp_b8.hlo.txt
//! arg a0 f32 8x800
//! arg a1 f32 800
//! out f32 8x10
//! end
//! ```

use anyhow::{bail, Context, Result};

/// Element type of an artifact argument/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    U32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "u32" => DType::U32,
            "i32" => DType::I32,
            other => bail!("unknown dtype tag {other:?}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Shape + dtype of one argument or output.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl ArgSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.element_count() * self.dtype.size_bytes()
    }
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect()
}

/// One compiled computation: HLO path + argument/output specs.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: String,
    pub args: Vec<ArgSpec>,
    pub outs: Vec<ArgSpec>,
}

/// The parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        let mut cur: Option<ArtifactSpec> = None;
        for (lno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            match toks[0] {
                "artifact" => {
                    if cur.is_some() {
                        bail!("line {}: nested artifact", lno + 1);
                    }
                    if toks.len() != 3 {
                        bail!("line {}: artifact needs name + path", lno + 1);
                    }
                    cur = Some(ArtifactSpec {
                        name: toks[1].to_string(),
                        hlo_path: toks[2].to_string(),
                        args: vec![],
                        outs: vec![],
                    });
                }
                "arg" => {
                    let a = cur.as_mut().context("arg outside artifact")?;
                    if toks.len() != 4 {
                        bail!("line {}: arg needs name dtype shape", lno + 1);
                    }
                    a.args.push(ArgSpec {
                        name: toks[1].to_string(),
                        dtype: DType::parse(toks[2])?,
                        dims: parse_dims(toks[3])?,
                    });
                }
                "out" => {
                    let a = cur.as_mut().context("out outside artifact")?;
                    if toks.len() != 3 {
                        bail!("line {}: out needs dtype shape", lno + 1);
                    }
                    a.outs.push(ArgSpec {
                        name: format!("out{}", a.outs.len()),
                        dtype: DType::parse(toks[1])?,
                        dims: parse_dims(toks[2])?,
                    });
                }
                "end" => {
                    m.artifacts
                        .push(cur.take().context("end outside artifact")?);
                }
                other => bail!("line {}: unknown directive {other:?}", lno + 1),
            }
        }
        if cur.is_some() {
            bail!("manifest truncated: missing `end`");
        }
        Ok(m)
    }

    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path}"))?;
        Manifest::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact mlp_b8 mlp_b8.hlo.txt
arg a0 f32 8x800
arg a1 u32 1024x25
out f32 8x10
end
artifact bmm bmm.hlo.txt
arg a0 u32 1024x32
out i32 1024x1024
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("mlp_b8").unwrap();
        assert_eq!(a.args.len(), 2);
        assert_eq!(a.args[0].dims, vec![8, 800]);
        assert_eq!(a.args[1].dtype, DType::U32);
        assert_eq!(a.outs[0].dims, vec![8, 10]);
        assert_eq!(a.args[1].byte_len(), 1024 * 25 * 4);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("arg a0 f32 8").is_err());
        assert!(Manifest::parse("artifact x y\narg a0 f32 8").is_err());
        assert!(Manifest::parse("artifact x y\nfrob\nend").is_err());
    }

    #[test]
    fn scalar_shape() {
        let m = Manifest::parse("artifact s s.hlo.txt\narg a0 f32 1\nout f32 1\nend")
            .unwrap();
        assert_eq!(m.artifacts[0].args[0].element_count(), 1);
    }
}
