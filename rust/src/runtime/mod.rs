//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Python never runs here — the interchange is HLO *text* (see aot.py for
//! why text rather than serialized protos) plus flat weight blobs
//! (`*.bin` / `*.meta`).

pub mod artifact;
pub mod blob;
pub mod executor;
pub mod mlp;

pub use artifact::{ArgSpec, ArtifactSpec, DType, Manifest};
pub use blob::{Blob, BlobWriter};
pub use executor::{Engine, LoadedModel, TensorData};
pub use mlp::MlpModel;
