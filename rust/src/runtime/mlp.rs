//! The AOT-compiled MNIST MLP as a servable `BatchModel`.
//!
//! Wraps the `mlp_b{8,32,128}` artifacts + the trained weight blob into
//! the coordinator's batch-execution interface.  Weights are converted
//! to TensorData once at load; each batch execution feeds the image
//! tensor plus the cached weight arguments.

use anyhow::{Context, Result};

use crate::coordinator::server::BatchModel;

use super::blob::Blob;
use super::executor::{Engine, TensorData};

pub const MLP_IN: usize = 800;
pub const MLP_CLASSES: usize = 10;
pub const MLP_BUCKETS: [usize; 3] = [8, 32, 128];

/// PJRT-backed MLP.
pub struct MlpModel {
    engine: Engine,
    /// weight literals pre-converted per batch bucket (§Perf opt-2: the
    /// 400 KB weight blob is converted to XLA literals once at load, so
    /// each request only converts its image tensor)
    prepared: Vec<(usize, Vec<xla::Literal>)>,
}

impl MlpModel {
    /// Load from an artifact directory (requires `make artifacts`).
    pub fn load(dir: &str) -> Result<MlpModel> {
        let mut engine = Engine::new(dir)?;
        let blob = Blob::load(&format!("{dir}/mlp_weights"))
            .context("mlp weight blob (run `make artifacts`)")?;
        let weight_args = weight_args_from_blob(&blob)?;
        // pre-compile all buckets (no first-request compile stall) and
        // pre-convert the weight tail for each
        let mut prepared = Vec::new();
        for b in MLP_BUCKETS {
            let model = engine.load(&format!("mlp_b{b}"))?;
            let tail = model.prepare_tail(1, &weight_args)?;
            prepared.push((b, tail));
        }
        Ok(MlpModel { engine, prepared })
    }

    /// Run one padded batch (must be a compiled bucket size).
    pub fn infer(&mut self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(images.len() == batch * MLP_IN, "bad image payload");
        let tail = &self
            .prepared
            .iter()
            .find(|(b, _)| *b == batch)
            .context("batch is not a compiled bucket")?
            .1;
        let model = self.engine.load(&format!("mlp_b{batch}"))?;
        let head = [TensorData::F32(images.to_vec())];
        let outs = model.run_prepared(&head, tail)?;
        Ok(outs[0].as_f32()?.to_vec())
    }
}

/// Blob -> the (in_thresh, w1..b4) argument tail of `mlp_forward`.
pub fn weight_args_from_blob(blob: &Blob) -> Result<Vec<TensorData>> {
    let mut args = vec![TensorData::F32(blob.as_f32("in_thresh")?)];
    for i in 1..=3 {
        args.push(TensorData::U32(blob.as_u32(&format!("w{i}"))?));
        args.push(TensorData::F32(blob.as_f32(&format!("t{i}"))?));
        args.push(TensorData::I32(blob.as_i32(&format!("f{i}"))?));
    }
    args.push(TensorData::U32(blob.as_u32("w4")?));
    args.push(TensorData::F32(blob.as_f32("g4")?));
    args.push(TensorData::F32(blob.as_f32("b4")?));
    Ok(args)
}

impl BatchModel for MlpModel {
    fn run_batch(&mut self, data: &[f32], padded: usize) -> Result<Vec<f32>> {
        self.infer(data, padded)
    }

    fn row_elems(&self) -> usize {
        MLP_IN
    }

    fn out_elems(&self) -> usize {
        MLP_CLASSES
    }

    fn buckets(&self) -> Vec<usize> {
        MLP_BUCKETS.to_vec()
    }
}
