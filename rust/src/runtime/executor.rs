//! PJRT execution engine: compiles HLO-text artifacts once at startup and
//! runs them with concrete tensors on the request path.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::artifact::{ArgSpec, ArtifactSpec, DType, Manifest};

/// Host-side tensor payload matching an ArgSpec.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    U32(Vec<u32>),
    I32(Vec<i32>),
}

impl TensorData {
    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::U32(_) => DType::U32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::U32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            TensorData::U32(v) => Ok(v),
            _ => bail!("tensor is not u32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    fn to_literal(&self, spec: &ArgSpec) -> Result<xla::Literal> {
        if self.dtype() != spec.dtype {
            bail!(
                "arg {}: dtype mismatch (got {:?}, want {:?})",
                spec.name,
                self.dtype(),
                spec.dtype
            );
        }
        if self.len() != spec.element_count() {
            bail!(
                "arg {}: element count {} != spec {:?}",
                spec.name,
                self.len(),
                spec.dims
            );
        }
        let (ty, bytes): (xla::ElementType, Vec<u8>) = match self {
            TensorData::F32(v) => (
                xla::ElementType::F32,
                v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
            TensorData::U32(v) => (
                xla::ElementType::U32,
                v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
            TensorData::I32(v) => (
                xla::ElementType::S32,
                v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, &spec.dims, &bytes)
            .map_err(|e| anyhow::anyhow!("literal create failed: {e:?}"))
    }

    fn from_literal(lit: &xla::Literal, spec: &ArgSpec) -> Result<TensorData> {
        Ok(match spec.dtype {
            DType::F32 => TensorData::F32(
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?,
            ),
            DType::U32 => TensorData::U32(
                lit.to_vec::<u32>()
                    .map_err(|e| anyhow::anyhow!("to_vec u32: {e:?}"))?,
            ),
            DType::I32 => TensorData::I32(
                lit.to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?,
            ),
        })
    }
}

/// One compiled artifact ready for execution.
pub struct LoadedModel {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Pre-convert a tail of the argument list (e.g. model weights) to
    /// XLA literals once, so the per-request path only converts the
    /// request tensors.  `from` is the spec index the tail starts at.
    pub fn prepare_tail(&self, from: usize, tail: &[TensorData]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(from + tail.len() == self.spec.args.len(), "tail mismatch");
        tail.iter()
            .zip(&self.spec.args[from..])
            .map(|(t, s)| t.to_literal(s))
            .collect()
    }

    /// Execute with `head` request tensors + a prepared literal tail
    /// (from `prepare_tail`) — the serving hot path.
    pub fn run_prepared(
        &self,
        head: &[TensorData],
        tail: &[xla::Literal],
    ) -> Result<Vec<TensorData>> {
        anyhow::ensure!(
            head.len() + tail.len() == self.spec.args.len(),
            "arg count mismatch"
        );
        let head_lits: Vec<xla::Literal> = head
            .iter()
            .zip(&self.spec.args[..head.len()])
            .map(|(t, s)| t.to_literal(s))
            .collect::<Result<_>>()?;
        let all: Vec<&xla::Literal> = head_lits.iter().chain(tail.iter()).collect();
        let bufs = self
            .exe
            .execute::<&xla::Literal>(&all)
            .map_err(|e| anyhow::anyhow!("execute failed: {e:?}"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal_sync: {e:?}"))?;
        let elems = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        elems
            .iter()
            .zip(&self.spec.outs)
            .map(|(l, s)| TensorData::from_literal(l, s))
            .collect()
    }

    /// Execute with host tensors; returns host tensors per output spec.
    pub fn run(&self, args: &[TensorData]) -> Result<Vec<TensorData>> {
        if args.len() != self.spec.args.len() {
            bail!(
                "{}: got {} args, want {}",
                self.spec.name,
                args.len(),
                self.spec.args.len()
            );
        }
        let literals: Vec<xla::Literal> = args
            .iter()
            .zip(&self.spec.args)
            .map(|(t, s)| t.to_literal(s))
            .collect::<Result<_>>()?;
        let bufs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute failed: {e:?}"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal_sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let elems = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        if elems.len() != self.spec.outs.len() {
            bail!(
                "{}: got {} outputs, want {}",
                self.spec.name,
                elems.len(),
                self.spec.outs.len()
            );
        }
        elems
            .iter()
            .zip(&self.spec.outs)
            .map(|(l, s)| TensorData::from_literal(l, s))
            .collect()
    }
}

/// The PJRT engine owning the client and all compiled artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: String,
    models: HashMap<String, LoadedModel>,
}

impl Engine {
    /// Create a CPU PJRT client and parse the manifest (compiles lazily).
    pub fn new(artifact_dir: &str) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Engine {
            client,
            manifest,
            dir: artifact_dir.to_string(),
            models: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) artifact by name.
    pub fn load(&mut self, name: &str) -> Result<&LoadedModel> {
        if !self.models.contains_key(name) {
            let spec = self
                .manifest
                .get(name)
                .with_context(|| format!("artifact {name:?} not in manifest"))?
                .clone();
            let path = format!("{}/{}", self.dir, spec.hlo_path);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parse {path}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            self.models
                .insert(name.to_string(), LoadedModel { spec, exe });
        }
        Ok(&self.models[name])
    }

    /// Convenience: load + run.
    pub fn run(&mut self, name: &str, args: &[TensorData]) -> Result<Vec<TensorData>> {
        self.load(name)?;
        self.models[name].run(args)
    }

    pub fn loaded_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }
}
