//! Tensor-layout co-design: first-class bit-tensor layouts, explicit
//! repack conversions, and the cost face the planner prices them with.
//!
//! The paper's central characterization finding is that "the stride of
//! memory access can significantly affect performance delivery and a
//! data-format co-design is highly desired" (§4): the FSB format of
//! §5.1 exists purely to pin the WMMA stride at 128, and the host
//! fastpath repacks everything into u64 lines for the same reason.
//! Before this module those conversions happened *implicitly* — u32
//! activation rows repacked to u64 inside every fastpath `bmm` call,
//! FSB images normalized on entry — with zero cost attribution, so the
//! planner optimized compute while silently paying un-modeled
//! conversion time between layers (PhoneBit's layout-aware operator
//! chaining is the same lesson on ARM hosts).
//!
//! This module makes layout a planned quantity:
//!
//! * [`LayoutKind`] — the closed set of packed-bit layouts the stack
//!   speaks: `Row32` (sequential u32 lines, the CUDA-facing general
//!   format), `Blocked64` (u32 word pairs fused into u64 lines, the
//!   host fastpath operand form), `Fsb` (the paper's fixed-stride
//!   8x128 tile format), and `Im2rowStaged` (u64 lines padded to
//!   128-bit stride boundaries — the alignment the fastpath's staged
//!   bit-im2row image uses).
//! * [`LayoutDesc`] — the concrete shape of one layout instance
//!   (lines x bits): word width, words per line / total words,
//!   alignment, storage bytes.  This is what repack costs are priced
//!   from.
//! * [`repack`] — exact, word-level converters between every ordered
//!   pair of kinds (the generalization of `bitops::pack64` into a
//!   registry), plus the hot-path row helpers the executor uses to
//!   materialize explicit repack ops through arena scratch.
//! * [`cost`] — the analytic repack bandwidth model
//!   (`CostSource::Analytic`'s answer for a layout edge); the tuner
//!   microbenches real conversion bandwidth per pair and fits it into
//!   the `CalibrationProfile` (schema v2), so `Calibrated`/`Live`
//!   sources price conversions from measurement.
//!
//! The planner's per-layer search is now a small dynamic program over
//! (scheme, layout) pairs: plans embed explicit layout edges and
//! repack ops (`PLAN_SCHEMA` v4), and the arena executor materializes
//! them — see `docs/ENGINE.md` ("Layouts & repack").

pub mod cost;
pub mod repack;

pub use repack::{BitImage, Words};

use std::fmt;

/// The packed-bit layouts the stack can plan, execute, and convert
/// between.  Order is significant: planner tie-breaks prefer the
/// earliest kind, so `Row32` (the universal default every backend
/// accepts) comes first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayoutKind {
    /// Sequential u32-packed lines (LSB-first) — the general format of
    /// `bitops::BitMatrix` / the executor's activation buffers.
    Row32,
    /// u32 word pairs fused into u64 words per line
    /// (`bitops::pack64::BitMatrix64`) — the host fastpath operand
    /// form; element order is unchanged, only the word width doubles.
    Blocked64,
    /// The paper's Fixed-Stride-Bit format (§5.1): (8 x 128)-bit tiles
    /// stored contiguously so every WMMA load uses `ldm = 128`.
    Fsb,
    /// u64 lines padded to 128-bit stride boundaries — the alignment
    /// the fastpath's staged bit-im2row image uses (`tap_words`
    /// padding), exposed as a first-class layout so staging buffers
    /// are priceable like any other conversion target.
    Im2rowStaged,
}

impl LayoutKind {
    /// Every kind, in planner tie-break order.
    pub fn all() -> [LayoutKind; 4] {
        [
            LayoutKind::Row32,
            LayoutKind::Blocked64,
            LayoutKind::Fsb,
            LayoutKind::Im2rowStaged,
        ]
    }

    /// Stable name (plan JSON v4, profile repack keys, bench entries).
    pub fn name(&self) -> &'static str {
        match self {
            LayoutKind::Row32 => "Row32",
            LayoutKind::Blocked64 => "Blocked64",
            LayoutKind::Fsb => "Fsb",
            LayoutKind::Im2rowStaged => "Im2rowStaged",
        }
    }

    /// Inverse of [`LayoutKind::name`] (case-insensitive; unknown names
    /// error with the full valid list, mirroring `Scheme::from_name`).
    pub fn from_name(s: &str) -> Result<LayoutKind, UnknownLayout> {
        LayoutKind::all()
            .into_iter()
            .find(|k| k.name().eq_ignore_ascii_case(s))
            .ok_or_else(|| UnknownLayout(s.to_string()))
    }

    /// Width of one packed word in bits.
    pub fn word_bits(&self) -> usize {
        match self {
            LayoutKind::Row32 | LayoutKind::Fsb => 32,
            LayoutKind::Blocked64 | LayoutKind::Im2rowStaged => 64,
        }
    }

    /// Required alignment of one line (or tile row) in bits — the
    /// stride unit the layout was designed around.
    pub fn align_bits(&self) -> usize {
        match self {
            LayoutKind::Row32 => 32,
            LayoutKind::Blocked64 => 64,
            // FSB tiles and the im2row staging both fix a 128-bit stride
            LayoutKind::Fsb | LayoutKind::Im2rowStaged => 128,
        }
    }

    /// The index of this kind in [`LayoutKind::all`] (planner DP slot).
    pub fn index(&self) -> usize {
        LayoutKind::all()
            .iter()
            .position(|k| k == self)
            .expect("every kind is in all()")
    }
}

impl fmt::Display for LayoutKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Error from [`LayoutKind::from_name`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnknownLayout(pub String);

impl fmt::Display for UnknownLayout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown layout {:?}; valid layouts: {}",
            self.0,
            LayoutKind::all().map(|k| k.name()).join(", ")
        )
    }
}

impl std::error::Error for UnknownLayout {}

/// The concrete shape of one layout instance: a logical `lines x bits`
/// bit tensor stored under `kind`.  Pad bits (beyond `bits` in a line,
/// beyond `lines` in an FSB tile column) are 0 by invariant — Eq 2
/// ignores them by construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayoutDesc {
    pub kind: LayoutKind,
    /// major extent (packed lines / activation rows)
    pub lines: usize,
    /// logical bits per line
    pub bits: usize,
}

impl LayoutDesc {
    pub fn new(kind: LayoutKind, lines: usize, bits: usize) -> LayoutDesc {
        LayoutDesc { kind, lines, bits }
    }

    /// Packed words per line for the line-contiguous kinds; for `Fsb`
    /// this is the words of one tile *row band* (tiles_x * TILE_WORDS —
    /// 8 logical lines share it, so prefer [`LayoutDesc::total_words`]
    /// for sizing).
    pub fn words_per_line(&self) -> usize {
        match self.kind {
            LayoutKind::Row32 => self.bits.div_ceil(32),
            LayoutKind::Blocked64 => self.bits.div_ceil(64),
            // full 128-bit (2-word) stride units per line
            LayoutKind::Im2rowStaged => self.bits.div_ceil(128) * 2,
            LayoutKind::Fsb => {
                self.bits.div_ceil(crate::bitops::fsb::BW)
                    * crate::bitops::fsb::TILE_WORDS
            }
        }
    }

    /// Total packed words of the image (u32 words for 32-bit kinds,
    /// u64 words for 64-bit kinds).
    pub fn total_words(&self) -> usize {
        match self.kind {
            LayoutKind::Fsb => {
                let ty = self.lines.div_ceil(crate::bitops::fsb::BH);
                let tx = self.bits.div_ceil(crate::bitops::fsb::BW);
                ty * tx * crate::bitops::fsb::TILE_WORDS
            }
            _ => self.lines * self.words_per_line(),
        }
    }

    /// Bytes of packed storage — the quantity repack costs stream.
    pub fn storage_bytes(&self) -> usize {
        self.total_words() * self.kind.word_bits() / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for k in LayoutKind::all() {
            assert_eq!(LayoutKind::from_name(k.name()).unwrap(), k);
            assert_eq!(LayoutKind::from_name(&k.name().to_lowercase()).unwrap(), k);
        }
        let err = LayoutKind::from_name("Col13").unwrap_err();
        assert!(err.to_string().contains("valid layouts"), "{err}");
        assert!(err.to_string().contains("Blocked64"), "{err}");
    }

    #[test]
    fn order_puts_row32_first() {
        assert_eq!(LayoutKind::all()[0], LayoutKind::Row32);
        for (i, k) in LayoutKind::all().into_iter().enumerate() {
            assert_eq!(k.index(), i);
        }
    }

    #[test]
    fn desc_sizes_match_the_concrete_formats() {
        // Row32 == BitMatrix row-major, Blocked64 == BitMatrix64
        let d32 = LayoutDesc::new(LayoutKind::Row32, 5, 70);
        assert_eq!(d32.words_per_line(), 3);
        assert_eq!(d32.total_words(), 15);
        assert_eq!(d32.storage_bytes(), 60);
        let d64 = LayoutDesc::new(LayoutKind::Blocked64, 5, 70);
        assert_eq!(d64.words_per_line(), 2);
        assert_eq!(d64.storage_bytes(), 80);
        // Fsb == FsbMatrix: 10x200 pads to 2x2 tiles of 32 words
        let df = LayoutDesc::new(LayoutKind::Fsb, 10, 200);
        assert_eq!(df.total_words(), 2 * 2 * crate::bitops::fsb::TILE_WORDS);
        assert_eq!(df.storage_bytes(), 512);
        // Im2rowStaged: 70 bits -> one 128-bit unit = 2 u64 words/line
        let ds = LayoutDesc::new(LayoutKind::Im2rowStaged, 5, 70);
        assert_eq!(ds.words_per_line(), 2);
        assert_eq!(ds.storage_bytes(), 80);
        // 129 bits -> two units = 4 words
        assert_eq!(
            LayoutDesc::new(LayoutKind::Im2rowStaged, 1, 129).words_per_line(),
            4
        );
    }

    #[test]
    fn alignment_and_word_width() {
        assert_eq!(LayoutKind::Row32.word_bits(), 32);
        assert_eq!(LayoutKind::Blocked64.word_bits(), 64);
        assert_eq!(LayoutKind::Fsb.align_bits(), 128);
        assert_eq!(LayoutKind::Im2rowStaged.align_bits(), 128);
    }
}
