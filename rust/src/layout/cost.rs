//! The analytic repack cost model — what `CostSource::Analytic` (and
//! every calibrated source, as its fallback) answers for a layout edge.
//!
//! A repack is pure streaming: read the source image, write the
//! destination image, plus one dispatch for the parallel section.  The
//! word-pairing conversions (`Row32 <-> Blocked64/Im2rowStaged`) run at
//! the host's streaming bandwidth; anything touching the FSB tile
//! order is an index-mapped word copy with a strided access pattern,
//! priced at a conservative fraction of it.  The tuner replaces these
//! constants with measured per-pair bandwidth
//! (`CalibrationProfile::repacks`, profile schema v2) on calibrated
//! hosts.

use crate::nn::cost::host;

use super::LayoutKind;

/// Bandwidth derating for conversions through the FSB tile order
/// (index-mapped strided word copies vs straight-line streaming).
pub const FSB_DERATE: f64 = 4.0;

/// Analytic seconds to convert `bytes` of total traffic (source bytes
/// + destination bytes) from `src` to `dst`.  Zero for the identity.
pub fn analytic_repack_secs(src: LayoutKind, dst: LayoutKind, bytes: usize) -> f64 {
    if src == dst {
        return 0.0;
    }
    let tiled = |k: LayoutKind| k == LayoutKind::Fsb;
    let rate = if tiled(src) || tiled(dst) {
        host::BYTES_PER_SEC / FSB_DERATE
    } else {
        host::BYTES_PER_SEC
    };
    bytes as f64 / rate + host::DISPATCH_SECS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_free_and_edges_cost_dispatch_plus_bytes() {
        assert_eq!(
            analytic_repack_secs(LayoutKind::Row32, LayoutKind::Row32, 1 << 20),
            0.0
        );
        let s = analytic_repack_secs(LayoutKind::Row32, LayoutKind::Blocked64, 0);
        assert_eq!(s, host::DISPATCH_SECS);
        let big = analytic_repack_secs(LayoutKind::Row32, LayoutKind::Blocked64, 1 << 30);
        assert!(big > s);
    }

    #[test]
    fn fsb_conversions_are_derated() {
        let plain =
            analytic_repack_secs(LayoutKind::Row32, LayoutKind::Blocked64, 1 << 20);
        let tiled = analytic_repack_secs(LayoutKind::Row32, LayoutKind::Fsb, 1 << 20);
        assert!(tiled > plain);
    }

    #[test]
    fn monotone_in_bytes_for_every_pair() {
        for (s, d) in super::super::repack::all_pairs() {
            let a = analytic_repack_secs(s, d, 1024);
            let b = analytic_repack_secs(s, d, 1 << 22);
            assert!(b > a, "{s}->{d}");
            assert!(a.is_finite() && a > 0.0);
        }
    }
}
