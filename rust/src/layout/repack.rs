//! Exact repack conversions between every pair of [`LayoutKind`]s —
//! the generalization of `bitops::pack64`'s u32↔u64 pairing into a
//! registry of converters.
//!
//! All conversions are word-level (pairing, splitting, or index-mapped
//! word copies — never per-bit loops on the common paths) and exact:
//! converting an image to any kind and back reproduces it bit for bit,
//! and pad bits stay 0 everywhere so Eq 2 is unaffected by any chain
//! of conversions (property-tested here and in
//! `rust/tests/bitops_prop.rs`).
//!
//! Non-adjacent pairs (e.g. `Blocked64 -> Fsb`) compose through the
//! `Row32` hub — the sequential general format every other layout is
//! defined against — so the registry covers every ordered pair in
//! [`all_pairs`] with two word-level passes at most.  The executor's
//! hot path uses the row-slice helpers ([`rows32_to_rows64`] /
//! [`rows64_to_rows32`]) directly over arena scratch, with no
//! allocation.

use crate::bitops::fsb::{BH, BW, TILE_ROW_WORDS, TILE_WORDS};
use crate::bitops::pack64::{repack64_into, unpack64_into, words64};

use super::{LayoutDesc, LayoutKind};

/// Packed storage of one image: u32 words for the 32-bit kinds, u64
/// words for the 64-bit kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum Words {
    W32(Vec<u32>),
    W64(Vec<u64>),
}

impl Words {
    /// The u32 view (panics on a 64-bit image).
    pub fn as_w32(&self) -> &[u32] {
        match self {
            Words::W32(v) => v,
            Words::W64(_) => panic!("expected a 32-bit word image"),
        }
    }

    /// The u64 view (panics on a 32-bit image).
    pub fn as_w64(&self) -> &[u64] {
        match self {
            Words::W64(v) => v,
            Words::W32(_) => panic!("expected a 64-bit word image"),
        }
    }
}

/// One packed bit image: a logical `lines x bits` tensor stored under
/// a concrete [`LayoutKind`].
#[derive(Clone, Debug, PartialEq)]
pub struct BitImage {
    pub desc: LayoutDesc,
    pub words: Words,
}

impl BitImage {
    /// Wrap sequential u32 lines (the `BitMatrix` row-major / arena
    /// activation form) as a `Row32` image.  Pad bits of each tail
    /// word are masked to 0 to uphold the layout invariant.
    pub fn from_rows32(lines: usize, bits: usize, mut data: Vec<u32>) -> BitImage {
        let desc = LayoutDesc::new(LayoutKind::Row32, lines, bits);
        assert_eq!(data.len(), desc.total_words(), "row32 payload size");
        let wpl = desc.words_per_line();
        let rem = bits % 32;
        if rem != 0 {
            let mask = (1u32 << rem) - 1;
            for l in 0..lines {
                data[l * wpl + wpl - 1] &= mask;
            }
        }
        BitImage { desc, words: Words::W32(data) }
    }

    /// Logical bit `(line, bit)` — per-kind index math, used by tests
    /// to cross-check the word-level converters.
    pub fn get_bit(&self, line: usize, bit: usize) -> bool {
        debug_assert!(line < self.desc.lines && bit < self.desc.bits);
        match (&self.words, self.desc.kind) {
            (Words::W32(v), LayoutKind::Row32) => {
                let wpl = self.desc.words_per_line();
                (v[line * wpl + bit / 32] >> (bit % 32)) & 1 == 1
            }
            (Words::W64(v), LayoutKind::Blocked64) => {
                let wpl = self.desc.words_per_line();
                (v[line * wpl + bit / 64] >> (bit % 64)) & 1 == 1
            }
            (Words::W64(v), LayoutKind::Im2rowStaged) => {
                let wpl = self.desc.words_per_line();
                (v[line * wpl + bit / 64] >> (bit % 64)) & 1 == 1
            }
            (Words::W32(v), LayoutKind::Fsb) => {
                let tiles_x = self.desc.bits.div_ceil(BW);
                let (ty, ry) = (line / BH, line % BH);
                let (tx, cx) = (bit / BW, bit % BW);
                let idx = (ty * tiles_x + tx) * TILE_WORDS
                    + ry * TILE_ROW_WORDS
                    + cx / 32;
                (v[idx] >> (cx % 32)) & 1 == 1
            }
            _ => unreachable!("word width always matches the kind"),
        }
    }
}

/// Every ordered (src, dst) pair of distinct layout kinds, in
/// `LayoutKind::all()` order — the converter registry's key set.  The
/// tuner microbenches each pair and the `tuner` bin fails if the
/// emitted profile is missing coefficients for any of them, so a new
/// `LayoutKind` variant automatically widens the required coverage.
pub fn all_pairs() -> Vec<(LayoutKind, LayoutKind)> {
    let mut out = Vec::new();
    for src in LayoutKind::all() {
        for dst in LayoutKind::all() {
            if src != dst {
                out.push((src, dst));
            }
        }
    }
    out
}

/// Stable key of one conversion direction (`"Row32->Blocked64"`) —
/// used by `CalibrationProfile` repack entries and bench names.
pub fn pair_name(src: LayoutKind, dst: LayoutKind) -> String {
    format!("{}->{}", src.name(), dst.name())
}

/// Convert an image to `dst` (identity conversions clone).  Exact:
/// `convert(&convert(&img, k), img.desc.kind) == img` for every kind.
pub fn convert(src: &BitImage, dst: LayoutKind) -> BitImage {
    if src.desc.kind == dst {
        return src.clone();
    }
    match src.desc.kind {
        LayoutKind::Row32 => from_row32(src, dst),
        _ => {
            let hub = to_row32(src);
            if dst == LayoutKind::Row32 {
                hub
            } else {
                from_row32(&hub, dst)
            }
        }
    }
}

fn from_row32(src: &BitImage, dst: LayoutKind) -> BitImage {
    debug_assert_eq!(src.desc.kind, LayoutKind::Row32);
    let (lines, bits) = (src.desc.lines, src.desc.bits);
    let wpl32 = src.desc.words_per_line();
    let data = src.words.as_w32();
    let ddesc = LayoutDesc::new(dst, lines, bits);
    match dst {
        LayoutKind::Blocked64 => {
            let wpl64 = ddesc.words_per_line();
            let mut out = vec![0u64; ddesc.total_words()];
            for l in 0..lines {
                repack64_into(
                    &data[l * wpl32..(l + 1) * wpl32],
                    &mut out[l * wpl64..(l + 1) * wpl64],
                );
            }
            BitImage { desc: ddesc, words: Words::W64(out) }
        }
        LayoutKind::Im2rowStaged => {
            // same u64 pairing, but each line is padded to a whole
            // number of 128-bit stride units (trailing words stay 0)
            let stride = ddesc.words_per_line();
            let used = words64(wpl32);
            let mut out = vec![0u64; ddesc.total_words()];
            for l in 0..lines {
                repack64_into(
                    &data[l * wpl32..(l + 1) * wpl32],
                    &mut out[l * stride..l * stride + used],
                );
            }
            BitImage { desc: ddesc, words: Words::W64(out) }
        }
        LayoutKind::Fsb => {
            // tile-order word copy, exactly FsbMatrix::from_bitmatrix
            let tiles_x = bits.div_ceil(BW);
            let mut out = vec![0u32; ddesc.total_words()];
            for l in 0..lines {
                let (ty, ry) = (l / BH, l % BH);
                for w in 0..wpl32 {
                    let (tx, wx) = (w / TILE_ROW_WORDS, w % TILE_ROW_WORDS);
                    out[(ty * tiles_x + tx) * TILE_WORDS + ry * TILE_ROW_WORDS + wx] =
                        data[l * wpl32 + w];
                }
            }
            BitImage { desc: ddesc, words: Words::W32(out) }
        }
        LayoutKind::Row32 => src.clone(),
    }
}

fn to_row32(src: &BitImage) -> BitImage {
    let (lines, bits) = (src.desc.lines, src.desc.bits);
    let ddesc = LayoutDesc::new(LayoutKind::Row32, lines, bits);
    let wpl32 = ddesc.words_per_line();
    let mut out = vec![0u32; ddesc.total_words()];
    match src.desc.kind {
        LayoutKind::Row32 => return src.clone(),
        LayoutKind::Blocked64 => {
            let wpl64 = src.desc.words_per_line();
            let data = src.words.as_w64();
            for l in 0..lines {
                unpack64_into(
                    &data[l * wpl64..(l + 1) * wpl64],
                    &mut out[l * wpl32..(l + 1) * wpl32],
                );
            }
        }
        LayoutKind::Im2rowStaged => {
            let stride = src.desc.words_per_line();
            let used = words64(wpl32);
            let data = src.words.as_w64();
            for l in 0..lines {
                unpack64_into(
                    &data[l * stride..l * stride + used],
                    &mut out[l * wpl32..(l + 1) * wpl32],
                );
            }
        }
        LayoutKind::Fsb => {
            let tiles_x = bits.div_ceil(BW);
            let data = src.words.as_w32();
            for l in 0..lines {
                let (ty, ry) = (l / BH, l % BH);
                for w in 0..wpl32 {
                    let (tx, wx) = (w / TILE_ROW_WORDS, w % TILE_ROW_WORDS);
                    out[l * wpl32 + w] = data
                        [(ty * tiles_x + tx) * TILE_WORDS + ry * TILE_ROW_WORDS + wx];
                }
            }
        }
    }
    BitImage { desc: ddesc, words: Words::W32(out) }
}

/// Hot-path `Row32 -> Blocked64` over raw row slices (the executor's
/// explicit repack op, run through pre-sized arena scratch with no
/// allocation).  `src` holds rows of `wpl32` u32 words; `dst` receives
/// the same rows as `words64(wpl32)` u64 words each.
pub fn rows32_to_rows64(src: &[u32], wpl32: usize, dst: &mut [u64]) {
    assert!(wpl32 > 0, "empty lines");
    let wpl64 = words64(wpl32);
    let rows = src.len() / wpl32;
    assert_eq!(src.len(), rows * wpl32, "whole rows only");
    assert_eq!(dst.len(), rows * wpl64, "dst row count");
    for (s, d) in src.chunks_exact(wpl32).zip(dst.chunks_exact_mut(wpl64)) {
        repack64_into(s, d);
    }
}

/// Hot-path `Blocked64 -> Row32` over raw row slices (the executor's
/// explicit back-conversion when a planned edge hands a u64 activation
/// to a `Row32`-native backend).
pub fn rows64_to_rows32(src: &[u64], wpl32: usize, dst: &mut [u32]) {
    assert!(wpl32 > 0, "empty lines");
    let wpl64 = words64(wpl32);
    let rows = dst.len() / wpl32;
    assert_eq!(dst.len(), rows * wpl32, "whole rows only");
    assert_eq!(src.len(), rows * wpl64, "src row count");
    for (s, d) in src.chunks_exact(wpl64).zip(dst.chunks_exact_mut(wpl32)) {
        unpack64_into(s, d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::{BitMatrix, BitMatrix64, FsbMatrix, Layout};
    use crate::util::proptest::run_cases;

    fn random_image(rng: &mut crate::util::Rng, lines: usize, bits: usize) -> BitImage {
        let m = BitMatrix::random(lines, bits, Layout::RowMajor, rng);
        BitImage::from_rows32(lines, bits, m.data)
    }

    #[test]
    fn registry_covers_every_ordered_pair() {
        let pairs = all_pairs();
        let n = LayoutKind::all().len();
        assert_eq!(pairs.len(), n * (n - 1));
        for (s, d) in &pairs {
            assert_ne!(s, d);
            assert!(pair_name(*s, *d).contains("->"));
        }
        assert_eq!(
            pair_name(LayoutKind::Row32, LayoutKind::Blocked64),
            "Row32->Blocked64"
        );
    }

    #[test]
    fn every_pair_roundtrips_exactly() {
        run_cases(301, 40, |rng| {
            let lines = 1 + rng.gen_range(40);
            let bits = 1 + rng.gen_range(300);
            let img = random_image(rng, lines, bits);
            for (src_k, dst_k) in all_pairs() {
                let there = convert(&convert(&img, src_k), dst_k);
                assert_eq!(there.desc.kind, dst_k);
                let back = convert(&there, LayoutKind::Row32);
                assert_eq!(back, img, "{} via {}", pair_name(src_k, dst_k), bits);
            }
        });
    }

    #[test]
    fn blocked64_matches_bitmatrix64_reference() {
        run_cases(302, 40, |rng| {
            let lines = 1 + rng.gen_range(30);
            let bits = 1 + rng.gen_range(260);
            let m = BitMatrix::random(lines, bits, Layout::RowMajor, rng);
            let img = BitImage::from_rows32(lines, bits, m.data.clone());
            let b64 = convert(&img, LayoutKind::Blocked64);
            assert_eq!(b64.words.as_w64(), &BitMatrix64::from_bitmatrix(&m).data[..]);
        });
    }

    #[test]
    fn fsb_matches_fsbmatrix_reference() {
        run_cases(303, 40, |rng| {
            let lines = 1 + rng.gen_range(30);
            let bits = 1 + rng.gen_range(260);
            let m = BitMatrix::random(lines, bits, Layout::RowMajor, rng);
            let img = BitImage::from_rows32(lines, bits, m.data.clone());
            let fsb = convert(&img, LayoutKind::Fsb);
            assert_eq!(fsb.words.as_w32(), &FsbMatrix::from_bitmatrix(&m).data[..]);
        });
    }

    #[test]
    fn staged_lines_are_stride_padded_and_zero_tailed() {
        let mut rng = crate::util::Rng::new(304);
        let img = random_image(&mut rng, 4, 96); // 96 bits: 2 used u64, 2-word stride
        let staged = convert(&img, LayoutKind::Im2rowStaged);
        assert_eq!(staged.desc.words_per_line(), 2);
        // 70 bits: 2 used of a 2-word stride (tail bits of word 1 zero)
        let img70 = random_image(&mut rng, 4, 70);
        let st70 = convert(&img70, LayoutKind::Im2rowStaged);
        for l in 0..4 {
            let line = &st70.words.as_w64()[l * 2..(l + 1) * 2];
            assert_eq!(line[1] >> 6, 0, "line {l} pad bits set");
        }
        // 129 bits: 3 used u64 words of a 4-word stride, last word zero
        let img129 = random_image(&mut rng, 3, 129);
        let st = convert(&img129, LayoutKind::Im2rowStaged);
        assert_eq!(st.desc.words_per_line(), 4);
        for l in 0..3 {
            assert_eq!(st.words.as_w64()[l * 4 + 3], 0, "line {l} stride pad set");
        }
        assert_eq!(convert(&st, LayoutKind::Row32), img129);
    }

    #[test]
    fn get_bit_agrees_with_row32_across_kinds() {
        run_cases(305, 25, |rng| {
            let lines = 1 + rng.gen_range(20);
            let bits = 1 + rng.gen_range(200);
            let img = random_image(rng, lines, bits);
            for k in LayoutKind::all() {
                let c = convert(&img, k);
                for _ in 0..20 {
                    let l = rng.gen_range(lines);
                    let b = rng.gen_range(bits);
                    assert_eq!(
                        c.get_bit(l, b),
                        img.get_bit(l, b),
                        "({l},{b}) under {k}"
                    );
                }
            }
        });
    }

    #[test]
    fn row_slice_helpers_match_the_image_converters() {
        run_cases(306, 40, |rng| {
            let rows = 1 + rng.gen_range(20);
            let bits = 1 + rng.gen_range(300);
            let img = random_image(rng, rows, bits);
            let wpl32 = img.desc.words_per_line();
            let wpl64 = words64(wpl32);
            let mut d64 = vec![0u64; rows * wpl64];
            rows32_to_rows64(img.words.as_w32(), wpl32, &mut d64);
            assert_eq!(
                &d64[..],
                convert(&img, LayoutKind::Blocked64).words.as_w64(),
                "{rows}x{bits}"
            );
            let mut back = vec![0u32; rows * wpl32];
            rows64_to_rows32(&d64, wpl32, &mut back);
            assert_eq!(&back[..], img.words.as_w32());
        });
    }

    #[test]
    fn degenerate_shapes_roundtrip() {
        let mut rng = crate::util::Rng::new(307);
        for (lines, bits) in [(1, 1), (1, 257), (257, 1), (8, 128), (9, 129)] {
            let img = random_image(&mut rng, lines, bits);
            for k in LayoutKind::all() {
                let back = convert(&convert(&img, k), LayoutKind::Row32);
                assert_eq!(back, img, "{lines}x{bits} via {k}");
            }
        }
    }
}
