//! Bit-matrix-multiplication schemes (§5.2, Tables 3–4, Figs 16–19).
//!
//! Problem convention: `A` is (m x k) row-major packed, `B` is (k x n)
//! column-major packed (packed columns == rows of B^T), output `C` is
//! (m x n) row-major i32 — the +/-1 product of Eq 2.

pub mod baselines;
pub mod bstc;
pub mod btc;

use crate::bitops::{BitMatrix, Layout};
use crate::sim::{Engine, KernelTrace, MemSpace};

use super::IoMode;

/// One BMM instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BmmProblem {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl BmmProblem {
    pub fn square(n: usize) -> BmmProblem {
        BmmProblem { m: n, n, k: n }
    }

    /// +/-1 multiply-accumulate ops (the TOPS numerator): 2*m*n*k.
    pub fn ops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// packed operand bytes (A + B).
    pub fn operand_bytes(&self) -> f64 {
        ((self.m * self.k + self.n * self.k) / 8) as f64
    }
}

/// A BMM scheme: functional algorithm + timing trace.
pub trait BmmScheme {
    /// Table 3 scheme tag (bmm32, bmmafmt, ...).
    fn name(&self) -> &'static str;

    /// Can this scheme run this problem/mode?  (e.g. HGEMM/Cutlass have
    /// no bit-output variant in Table 4.)
    fn supports(&self, p: BmmProblem, mode: IoMode) -> bool {
        let _ = mode;
        p.m % 8 == 0 && p.n % 8 == 0 && p.k % 128 == 0
    }

    /// Bit-exact +/-1 product (m x n row-major i32).
    fn compute(&self, a: &BitMatrix, b: &BitMatrix) -> Vec<i32>;

    /// Kernel launches for this problem under the given IO protocol.
    fn traces(&self, p: BmmProblem, mode: IoMode) -> Vec<KernelTrace>;

    /// Whether the scheme runs on the tensor cores (Table 3 grouping).
    fn uses_tensorcores(&self) -> bool;

    /// Fused binarized output (BNN-specific protocol): threshold at
    /// `thresh[j]` per output column, repacked row-major.
    fn compute_bin(&self, a: &BitMatrix, b: &BitMatrix, thresh: &[f32]) -> BitMatrix {
        let c = self.compute(a, b);
        let (m, n) = (a.rows, b.cols);
        let mut out = BitMatrix::zeros(m, n, Layout::RowMajor);
        for r in 0..m {
            for j in 0..n {
                if (c[r * n + j] as f32) >= thresh[j] {
                    out.set(r, j, true);
                }
            }
        }
        out
    }
}

/// Simulated wall time (seconds) of a scheme on a problem.
pub fn simulate(engine: &Engine, s: &dyn BmmScheme, p: BmmProblem, mode: IoMode) -> f64 {
    s.traces(p, mode)
        .iter()
        .map(|t| engine.cost(t).total_secs)
        .sum()
}

/// Simulated TOPS (2*m*n*k ops over simulated seconds).
pub fn simulate_tops(engine: &Engine, s: &dyn BmmScheme, p: BmmProblem, mode: IoMode) -> f64 {
    p.ops() / simulate(engine, s, p, mode) / 1e12
}

/// The naive Eq-2 reference every scheme must match.
pub fn naive_ref(a: &BitMatrix, b: &BitMatrix) -> Vec<i32> {
    assert_eq!(a.layout, Layout::RowMajor);
    assert_eq!(b.layout, Layout::ColMajor);
    assert_eq!(a.cols, b.rows);
    let (m, n, k) = (a.rows, b.cols, a.cols);
    let mut out = vec![0i32; m * n];
    for r in 0..m {
        let ar = a.line(r);
        for j in 0..n {
            out[r * n + j] = crate::bitops::pack::pm1_dot(ar, b.line(j), k);
        }
    }
    out
}

/// Trace of a ballot-style binarize kernel over `elems` f32 elements
/// (the General-mode preprocessing of A and B, §5.2(a)).
pub fn binarize_trace(name: &str, elems: usize) -> KernelTrace {
    let mut t = KernelTrace::new(name);
    // 8 warps per CTA, each warp binarizes 32*32 = 1024 elements
    let elems_per_warp = 1024;
    let warps = elems.div_ceil(elems_per_warp);
    t.warps_per_cta = 8;
    t.grid_ctas = warps.div_ceil(8).max(1);
    t.warp.bulk_load_bytes = elems_per_warp * 4;
    t.warp.bulk_store_bytes = elems_per_warp / 8;
    t.warp.intu_ops = elems_per_warp + 32; // compare + __ballot
    t.compulsory_bytes = (elems * 4 + elems / 8) as f64;
    t
}

/// Append the shared General-mode pre/post kernels around a scheme's
/// core traces: binarize(A), binarize(B) (the int32 C store is already
/// part of each core trace).
pub fn with_general_io(core: Vec<KernelTrace>, p: BmmProblem) -> Vec<KernelTrace> {
    let mut v = vec![
        binarize_trace("binarize_a", p.m * p.k),
        binarize_trace("binarize_b", p.k * p.n),
    ];
    v.extend(core);
    v
}

/// All Table-3/4 schemes, in table order.
pub fn all_schemes() -> Vec<Box<dyn BmmScheme>> {
    vec![
        Box::new(baselines::CublasHgemm),
        Box::new(baselines::XnorBmm),
        Box::new(bstc::BstcBmm::new(32, false)),
        Box::new(bstc::BstcBmm::new(64, false)),
        Box::new(bstc::BstcBmm::new(32, true)),
        Box::new(bstc::BstcBmm::new(64, true)),
        Box::new(baselines::CutlassBmm),
        Box::new(baselines::CutlassUint4),
        Box::new(btc::Design1),
        Box::new(btc::Design2),
        Box::new(btc::Design3),
    ]
}

/// Set the compulsory/footprint fields for a bit-operand BMM trace.
pub(crate) fn attach_footprints(t: &mut KernelTrace, p: BmmProblem, mode: IoMode) {
    t.compulsory_bytes = bit_compulsory(p, mode);
    t.load_footprint_bytes = p.operand_bytes();
}

/// Standard store-side trace elements for the two IO protocols.
pub(crate) fn attach_output(
    t: &mut KernelTrace,
    mode: IoMode,
    out_tiles_per_warp: usize,
) {
    match mode {
        IoMode::General => {
            t.warp.store_tiles(MemSpace::Global, out_tiles_per_warp);
        }
        IoMode::BnnSpecific => {
            // __ballot binarization + packed store (8 bytes per 8x8 tile)
            t.warp.intu_ops += 80 * out_tiles_per_warp;
            t.warp.bulk_store_bytes += 8 * out_tiles_per_warp;
        }
    }
}

/// Compulsory footprint for bit-operand schemes.
pub(crate) fn bit_compulsory(p: BmmProblem, mode: IoMode) -> f64 {
    let out = match mode {
        IoMode::General => (p.m * p.n * 4) as f64,
        IoMode::BnnSpecific => (p.m * p.n / 8) as f64,
    };
    p.operand_bytes() + out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RTX2080TI;
    use crate::util::Rng;

    #[test]
    fn all_schemes_match_naive_ref() {
        let mut rng = Rng::new(7);
        for p in [
            BmmProblem { m: 16, n: 128, k: 128 },
            BmmProblem { m: 64, n: 256, k: 256 },
            BmmProblem { m: 128, n: 128, k: 384 },
        ] {
            let a = BitMatrix::random(p.m, p.k, Layout::RowMajor, &mut rng);
            let b = BitMatrix::random(p.k, p.n, Layout::ColMajor, &mut rng);
            let want = naive_ref(&a, &b);
            for s in all_schemes() {
                if !s.supports(p, IoMode::General) {
                    continue;
                }
                assert_eq!(
                    s.compute(&a, &b),
                    want,
                    "scheme {} disagrees on {:?}",
                    s.name(),
                    p
                );
            }
        }
    }

    #[test]
    fn compute_bin_packs_threshold() {
        let mut rng = Rng::new(8);
        let p = BmmProblem { m: 8, n: 128, k: 128 };
        let a = BitMatrix::random(p.m, p.k, Layout::RowMajor, &mut rng);
        let b = BitMatrix::random(p.k, p.n, Layout::ColMajor, &mut rng);
        let thresh = vec![0.0f32; p.n];
        let s = btc::Design3;
        let packed = s.compute_bin(&a, &b, &thresh);
        let c = s.compute(&a, &b);
        for r in 0..p.m {
            for j in 0..p.n {
                assert_eq!(packed.get(r, j), c[r * p.n + j] >= 0);
            }
        }
    }

    #[test]
    fn traces_exist_for_supported_modes() {
        let e = Engine::new(&RTX2080TI);
        let p = BmmProblem::square(1024);
        for s in all_schemes() {
            for mode in [IoMode::General, IoMode::BnnSpecific] {
                if s.supports(p, mode) {
                    let t = simulate(&e, s.as_ref(), p, mode);
                    assert!(t > 0.0, "{} {:?}", s.name(), mode);
                }
            }
        }
    }

    #[test]
    fn design3_beats_design1_at_mid_sizes() {
        // the paper's headline §7.2 observation (II)
        let e = Engine::new(&RTX2080TI);
        for n in [2048usize, 4096] {
            let p = BmmProblem::square(n);
            let d1 = simulate(&e, &btc::Design1, p, IoMode::General);
            let d3 = simulate(&e, &btc::Design3, p, IoMode::General);
            assert!(d3 < d1, "n={n}: design3 {d3} !< design1 {d1}");
        }
    }

    #[test]
    fn specific_mode_faster_than_general() {
        let e = Engine::new(&RTX2080TI);
        let p = BmmProblem::square(4096);
        let g = simulate(&e, &btc::Design3, p, IoMode::General);
        let s = simulate(&e, &btc::Design3, p, IoMode::BnnSpecific);
        assert!(s < g, "specific {s} !< general {g}");
    }
}
