//! BSTC software BMM baselines (Li et al., SC'19 — reference [26]):
//! binarized-soft-tensor-core running on the conventional INT units and
//! SFUs, with 32x32 or 64x64 bit tiles, plus the "fine-grained" variants
//! that additionally split the K dimension for small-matrix occupancy.

use crate::bitops::BitMatrix;
use crate::sim::KernelTrace;

use super::super::IoMode;
use super::{attach_footprints, attach_output, with_general_io, BmmProblem, BmmScheme};

/// BSTC BMM with tile size 32 or 64; `fine` adds K-splitting
/// (bmm32/bmm64/bmms32/bmms64 in Tables 3–4).
pub struct BstcBmm {
    pub tile: usize,
    pub fine: bool,
}

impl BstcBmm {
    pub fn new(tile: usize, fine: bool) -> BstcBmm {
        assert!(tile == 32 || tile == 64);
        BstcBmm { tile, fine }
    }

    /// K-slice bits handled per warp in the fine-grained variant.
    const FINE_KSLICE: usize = 1024;
}

impl BmmScheme for BstcBmm {
    fn name(&self) -> &'static str {
        match (self.tile, self.fine) {
            (32, false) => "bmm32",
            (64, false) => "bmm64",
            (32, true) => "bmms32",
            (64, true) => "bmms64",
            _ => unreachable!(),
        }
    }

    fn uses_tensorcores(&self) -> bool {
        false
    }

    fn supports(&self, p: BmmProblem, _mode: IoMode) -> bool {
        p.m % self.tile == 0 && p.n % self.tile == 0 && p.k % 32 == 0
    }

    fn compute(&self, a: &BitMatrix, b: &BitMatrix) -> Vec<i32> {
        let (m, n, k) = (a.rows, b.cols, a.cols);
        let kw = k / 32;
        let t = self.tile;
        let mut out = vec![0i32; m * n];
        // tile loop mirrors the warp decomposition (tile x tile outputs);
        // 64-bit variant consumes two words per step like its u64 loads.
        let step = t / 32; // 1 for 32, 2 for 64
        for bm in (0..m).step_by(t) {
            for bn in (0..n).step_by(t) {
                for ks in (0..kw).step_by(step) {
                    let kend = (ks + step).min(kw);
                    for r in 0..t {
                        let ar = &a.line(bm + r)[ks..kend];
                        for c in 0..t {
                            let bc = &b.line(bn + c)[ks..kend];
                            let mut p = 0u32;
                            if t == 64 && kend - ks == 2 {
                                // genuine u64 xor+popc path
                                let x = (ar[0] as u64 | (ar[1] as u64) << 32)
                                    ^ (bc[0] as u64 | (bc[1] as u64) << 32);
                                p = x.count_ones();
                            } else {
                                for (x, y) in ar.iter().zip(bc.iter()) {
                                    p += (x ^ y).count_ones();
                                }
                            }
                            out[(bm + r) * n + bn + c] +=
                                ((kend - ks) * 32) as i32 - 2 * p as i32;
                        }
                    }
                }
            }
        }
        out
    }

    fn traces(&self, p: BmmProblem, mode: IoMode) -> Vec<KernelTrace> {
        let t = self.tile;
        let mut tr = KernelTrace::new(self.name());
        let kslice = if self.fine {
            Self::FINE_KSLICE.min(p.k)
        } else {
            p.k
        };
        let kparts = p.k.div_ceil(kslice);
        let warps = (p.m / t) * (p.n / t) * kparts;
        tr.warps_per_cta = 4;
        tr.grid_ctas = warps.div_ceil(4).max(1);
        // word-ops for the slice this warp owns
        let words = kslice / 32;
        let word_ops = t * t * words; // (row, col, word) triples
        match t {
            32 => {
                tr.warp.intu_ops = 2 * word_ops; // xor + iadd
                tr.warp.sfu_ops = word_ops; // popc
            }
            _ => {
                // u64: half the instructions, xor costs 2 lanes each
                let w64 = word_ops / 2;
                tr.warp.intu_ops = 2 * w64 + w64; // xor(2) + iadd(1)
                tr.warp.sfu_ops = w64; // popc64
            }
        }
        // loads: tile rows of A and B, coalesced word loads
        tr.warp.bulk_load_bytes = 2 * t * (kslice / 8);
        if self.fine && kparts > 1 {
            // partial-sum atomics back to global
            tr.warp.bulk_store_bytes += t * t * 4;
            tr.warp.intu_ops += t * t;
        }
        attach_output(&mut tr, mode, (t / 8) * (t / 8));
        attach_footprints(&mut tr, p, mode);
        match mode {
            IoMode::General => with_general_io(vec![tr], p),
            IoMode::BnnSpecific => vec![tr],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::Layout;
    use crate::kernels::bmm::{naive_ref, simulate};
    use crate::sim::{Engine, RTX2080TI};
    use crate::util::Rng;

    #[test]
    fn u64_path_matches_u32_path() {
        let mut rng = Rng::new(11);
        let a = BitMatrix::random(64, 256, Layout::RowMajor, &mut rng);
        let b = BitMatrix::random(256, 64, Layout::ColMajor, &mut rng);
        let want = naive_ref(&a, &b);
        assert_eq!(BstcBmm::new(32, false).compute(&a, &b), want);
        assert_eq!(BstcBmm::new(64, false).compute(&a, &b), want);
        assert_eq!(BstcBmm::new(64, true).compute(&a, &b), want);
    }

    #[test]
    fn fine_grained_wins_on_small_matrices() {
        // §7.2 (I): "for small matrices the fine-grained 64-bit BSTC is
        // relatively better" — driven by SM occupancy.
        let e = Engine::new(&RTX2080TI);
        let p = BmmProblem::square(256);
        let coarse = simulate(&e, &BstcBmm::new(64, false), p, IoMode::General);
        let fine = simulate(&e, &BstcBmm::new(64, true), p, IoMode::General);
        assert!(fine <= coarse, "fine {fine} !<= coarse {coarse}");
    }

    #[test]
    fn bstc_is_not_tensorcore() {
        assert!(!BstcBmm::new(32, false).uses_tensorcores());
    }

    #[test]
    fn names_match_tables() {
        assert_eq!(BstcBmm::new(32, false).name(), "bmm32");
        assert_eq!(BstcBmm::new(64, true).name(), "bmms64");
    }
}
