//! Reference/vendor baselines of Table 3: cuBLAS FP16 HGEMM, the original
//! XNOR-kernel BMM of Courbariaux et al. [1], Cutlass experimental BMM,
//! and Cutlass uint4 GEMM.

use crate::bitops::BitMatrix;
use crate::sim::{KernelTrace, MemSpace};

use super::super::IoMode;
use super::{bit_compulsory, naive_ref, with_general_io, BmmProblem, BmmScheme};

// ---------------------------------------------------------------------------
// cuBLAS HGEMM (FP16 tensor cores) — the paper's baseline ("1x")
// ---------------------------------------------------------------------------

/// Simulating BMM via FP16 HGEMM on the TCUs (cuBLAS).  Functionally the
/// +/-1 product is identical; the cost model is a 128x128-tiled FP16
/// GEMM at HMMA rates with fp16 operand traffic.
pub struct CublasHgemm;

impl BmmScheme for CublasHgemm {
    fn name(&self) -> &'static str {
        "hgemm"
    }

    fn uses_tensorcores(&self) -> bool {
        true
    }

    fn supports(&self, p: BmmProblem, mode: IoMode) -> bool {
        // no bit-output variant in Table 4
        mode == IoMode::General && p.m % 128 == 0 && p.n % 128 == 0 && p.k % 16 == 0
    }

    fn compute(&self, a: &BitMatrix, b: &BitMatrix) -> Vec<i32> {
        // numerically: +/-1 values fit fp16 exactly for k <= 2048 and the
        // i32-accumulated reference is what cuBLAS(+f32 acc) returns.
        naive_ref(a, b)
    }

    fn traces(&self, p: BmmProblem, _mode: IoMode) -> Vec<KernelTrace> {
        let mut t = KernelTrace::new("hgemm");
        t.warps_per_cta = 8;
        t.grid_ctas = ((p.m / 128) * (p.n / 128)).max(1);
        t.smem_per_cta = 32 * 1024; // double-buffered fp16 stages
        // per warp: 1/8 of the CTA's 128x128xK FMAs
        t.warp.hmma_fmas = 128 * 128 / 8 * p.k;
        // fp16 operand staging per CTA per 32-deep k-step: (128x32)x2x2B
        let ksteps = p.k / 32;
        t.warp.bulk_load_bytes = ksteps * 2 * (128 * 32 * 2) / 8;
        t.warp.bulk_store_bytes = 128 * 128 * 4 / 8;
        t.warp.cta_syncs = 2 * ksteps;
        // fp16 A + B + int C footprint
        t.compulsory_bytes =
            (2 * (p.m * p.k + p.k * p.n) + 4 * p.m * p.n) as f64;
        t.load_footprint_bytes = (2 * (p.m * p.k + p.k * p.n)) as f64;
        t.wave_bytes_per_cta = 32.0 * 1024.0; // swizzled k-step panels
        vec![t]
    }
}

// ---------------------------------------------------------------------------
// The original XNOR GPU kernel of [1] (unoptimized baseline "BMM")
// ---------------------------------------------------------------------------

/// Courbariaux et al.'s proof-of-concept GPU kernel: one thread per
/// output element, B-column accesses uncoalesced — the "1% utilization"
/// regime the BSTC paper criticizes.
pub struct XnorBmm;

impl BmmScheme for XnorBmm {
    fn name(&self) -> &'static str {
        "xnor_bmm"
    }

    fn uses_tensorcores(&self) -> bool {
        false
    }

    fn supports(&self, p: BmmProblem, mode: IoMode) -> bool {
        mode == IoMode::General && p.m % 8 == 0 && p.n % 32 == 0 && p.k % 32 == 0
    }

    fn compute(&self, a: &BitMatrix, b: &BitMatrix) -> Vec<i32> {
        naive_ref(a, b)
    }

    fn traces(&self, p: BmmProblem, _mode: IoMode) -> Vec<KernelTrace> {
        let mut t = KernelTrace::new("xnor_bmm");
        let threads = p.m * p.n;
        t.warps_per_cta = 8;
        t.grid_ctas = (threads / 32).div_ceil(8).max(1);
        let words = p.k / 32;
        // per warp: 32 output elements; A row words coalesce across the
        // warp only when the 32 lanes share a row — here lanes span a
        // row of C, so A loads broadcast (fine) but B columns stride by
        // k bits: every lane-word is its own 32B sector.
        t.warp.bulk_load_bytes = words * 4 /* A broadcast */
            + 32 * words * 32 /* B: full sector per 4B word */;
        t.warp.intu_ops = 2 * 32 * words;
        t.warp.sfu_ops = 32 * words;
        t.warp.bulk_store_bytes = 32 * 4;
        t.compulsory_bytes = bit_compulsory(p, IoMode::General);
        t.load_footprint_bytes = p.operand_bytes();
        with_general_io(vec![t], p)
    }
}

// ---------------------------------------------------------------------------
// Cutlass experimental BMM (TCU) and uint4 GEMM (TCU)
// ---------------------------------------------------------------------------

/// Cutlass's experimental WMMA b1 GEMM: sequential bit format (ldm =
/// matrix width) with shared-memory staging — between Design-1 and the
/// FSB design.  Cutlass computes the 0/1 dot product; the harness applies
/// the Eq-2 affine fix-up, so `compute` returns +/-1 semantics.
pub struct CutlassBmm;

impl BmmScheme for CutlassBmm {
    fn name(&self) -> &'static str {
        "cutlass"
    }

    fn uses_tensorcores(&self) -> bool {
        true
    }

    fn supports(&self, p: BmmProblem, mode: IoMode) -> bool {
        mode == IoMode::General && p.m % 8 == 0 && p.n % 8 == 0 && p.k % 128 == 0
    }

    fn compute(&self, a: &BitMatrix, b: &BitMatrix) -> Vec<i32> {
        // 0/1 dot product (popc(a xor b)) then Eq-2 conversion v = k - 2p
        let (m, n, k) = (a.rows, b.cols, a.cols);
        let mut out = vec![0i32; m * n];
        for r in 0..m {
            for c in 0..n {
                let p = crate::bitops::pack::xor_popc(a.line(r), b.line(c));
                out[r * n + c] = k as i32 - 2 * p as i32;
            }
        }
        out
    }

    fn traces(&self, p: BmmProblem, _mode: IoMode) -> Vec<KernelTrace> {
        let mut t = KernelTrace::new("cutlass");
        let warps = (p.m / 8) * (p.n / 8);
        t.warps_per_cta = 8;
        t.grid_ctas = warps.div_ceil(8).max(1);
        t.smem_per_cta = 8 * 1024;
        let ksteps = p.k / 128;
        // global loads in the sequential format (slow strides) staged to
        // shared, then fast shared-side WMMA loads
        t.warp.load_tiles(p.k, MemSpace::Global, 2 * ksteps);
        t.warp.load_tiles(128, MemSpace::Shared, 2 * ksteps);
        t.warp.bmma_same_acc_ops = ksteps;
        t.warp.cta_syncs = ksteps;
        t.warp.store_tiles(MemSpace::Global, 1);
        t.compulsory_bytes = bit_compulsory(p, IoMode::General);
        t.load_footprint_bytes = p.operand_bytes();
        t.wave_bytes_per_cta = (2 * 128 * p.k / 8) as f64;
        vec![t]
    }
}

/// Cutlass uint4 GEMM on the TCUs (m8n8k32 int4 mode): 4 bits per
/// element = 4x the operand traffic of b1 and 1/4 the elements per MMA.
pub struct CutlassUint4;

impl BmmScheme for CutlassUint4 {
    fn name(&self) -> &'static str {
        "cutlass_u4"
    }

    fn uses_tensorcores(&self) -> bool {
        true
    }

    fn supports(&self, p: BmmProblem, mode: IoMode) -> bool {
        mode == IoMode::General && p.m % 8 == 0 && p.n % 8 == 0 && p.k % 32 == 0
    }

    fn compute(&self, a: &BitMatrix, b: &BitMatrix) -> Vec<i32> {
        // uint4 encoding of +/-1: 1 -> 1, -1 -> 0 with the same affine
        // fix-up (v = 4p - ... ) — net result equals the Eq-2 product.
        naive_ref(a, b)
    }

    fn traces(&self, p: BmmProblem, _mode: IoMode) -> Vec<KernelTrace> {
        let mut t = KernelTrace::new("cutlass_u4");
        let warps = (p.m / 8) * (p.n / 8);
        t.warps_per_cta = 8;
        t.grid_ctas = warps.div_ceil(8).max(1);
        t.smem_per_cta = 8 * 1024;
        let ksteps = p.k / 32; // m8n8k32: 4x the steps of b1's k128
        // int4 tile rows are 32 elems x 4 bits = 16B, stride k*4 bits
        t.warp.load_tiles(4 * p.k, MemSpace::Global, 2 * ksteps);
        t.warp.int4_macs = 8 * 8 * 32 * ksteps;
        t.warp.store_tiles(MemSpace::Global, 1);
        // uint4 operands: k/2 bytes per row
        t.compulsory_bytes =
            ((p.m * p.k + p.n * p.k) / 2 + 4 * p.m * p.n) as f64;
        t.load_footprint_bytes = ((p.m * p.k + p.n * p.k) / 2) as f64;
        vec![t]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::Layout;
    use crate::kernels::bmm::{simulate, simulate_tops};
    use crate::sim::{Engine, RTX2080TI};
    use crate::util::Rng;

    #[test]
    fn cutlass_zero_one_fixup_is_eq2() {
        let mut rng = Rng::new(13);
        let a = BitMatrix::random(16, 128, Layout::RowMajor, &mut rng);
        let b = BitMatrix::random(128, 16, Layout::ColMajor, &mut rng);
        assert_eq!(CutlassBmm.compute(&a, &b), naive_ref(&a, &b));
    }

    #[test]
    fn bmm_beats_uint4_on_tcus() {
        // §7.2 (III): b1 dominates uint4 on the same TCUs
        let e = Engine::new(&RTX2080TI);
        for n in [1024usize, 4096] {
            let p = BmmProblem::square(n);
            let b1 = simulate(&e, &super::super::btc::Design3, p, IoMode::General);
            let u4 = simulate(&e, &CutlassUint4, p, IoMode::General);
            assert!(b1 < u4, "n={n}: b1 {b1} !< u4 {u4}");
        }
    }

    #[test]
    fn btc_design3_beats_hgemm_by_a_lot_at_4k() {
        // Fig 17: >12x over FP16 cuBLAS at 4K (specific vs general —
        // compare general-to-general here, expect >3x)
        let e = Engine::new(&RTX2080TI);
        let p = BmmProblem::square(4096);
        let h = simulate_tops(&e, &CublasHgemm, p, IoMode::General);
        let d3 = simulate_tops(&e, &super::super::btc::Design3, p, IoMode::General);
        assert!(d3 / h > 3.0, "speedup {}", d3 / h);
        // sanity: HGEMM lands in a plausible TFLOPS band for a 2080Ti
        assert!(h > 20.0 && h < 110.0, "hgemm TOPS {h}");
    }

    #[test]
    fn xnor_kernel_is_terrible() {
        // the "1% utilization" regime: BSTC should crush it
        let e = Engine::new(&RTX2080TI);
        let p = BmmProblem::square(1024);
        let xnor = simulate(&e, &XnorBmm, p, IoMode::General);
        let bstc = simulate(
            &e,
            &super::super::bstc::BstcBmm::new(64, false),
            p,
            IoMode::General,
        );
        assert!(xnor > 3.0 * bstc, "xnor {xnor} vs bstc {bstc}");
    }
}
