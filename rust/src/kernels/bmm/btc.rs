//! The paper's three BTC BMM designs (§5.2, Listings 3–5).

use crate::bitops::{fsb, BitMatrix, FsbMatrix};
use crate::sim::{KernelTrace, MemSpace};

use super::super::IoMode;
use super::{attach_footprints, attach_output, with_general_io, BmmProblem, BmmScheme};

/// Eq-2 product for one 8x8 output tile given packed word slices.
#[inline]
fn tile_mma(
    out: &mut [i32],
    n: usize,
    row0: usize,
    col0: usize,
    a_rows: &[&[u32]],
    b_cols: &[&[u32]],
) {
    for (ri, ar) in a_rows.iter().enumerate() {
        for (ci, bc) in b_cols.iter().enumerate() {
            let mut p = 0u32;
            for (x, y) in ar.iter().zip(bc.iter()) {
                p += (x ^ y).count_ones();
            }
            out[(row0 + ri) * n + col0 + ci] += (ar.len() * 32) as i32 - 2 * p as i32;
        }
    }
}

// ---------------------------------------------------------------------------
// Design-1: baseline WMMA (Listing 3)
// ---------------------------------------------------------------------------

/// Design-1 (`bmma`): one warp per 8x8 output tile, K-loop of bmma_sync
/// into the same accumulator, operands loaded straight from global
/// memory with ldm = matrix width.
pub struct Design1;

impl BmmScheme for Design1 {
    fn name(&self) -> &'static str {
        "bmma"
    }

    fn uses_tensorcores(&self) -> bool {
        true
    }

    fn compute(&self, a: &BitMatrix, b: &BitMatrix) -> Vec<i32> {
        let (m, n, k) = (a.rows, b.cols, a.cols);
        let mut out = vec![0i32; m * n];
        let kw = k / 32;
        // warp loop: one 8x8 tile at a time, 128-bit K steps
        for bt in (0..m).step_by(8) {
            for by in (0..n).step_by(8) {
                for ks in (0..kw).step_by(4) {
                    let kend = (ks + 4).min(kw);
                    let a_rows: Vec<&[u32]> =
                        (0..8).map(|r| &a.line(bt + r)[ks..kend]).collect();
                    let b_cols: Vec<&[u32]> =
                        (0..8).map(|c| &b.line(by + c)[ks..kend]).collect();
                    tile_mma(&mut out, n, bt, by, &a_rows, &b_cols);
                }
            }
        }
        out
    }

    fn traces(&self, p: BmmProblem, mode: IoMode) -> Vec<KernelTrace> {
        let mut t = KernelTrace::new("bmma");
        let warps = (p.m / 8) * (p.n / 8);
        t.warps_per_cta = 2; // Listing 3: two warps per CTA for occupancy
        t.grid_ctas = warps.div_ceil(2).max(1);
        let ksteps = p.k / 128;
        // operands in the sequential format: ldm = matrix width (k)
        t.warp.load_tiles(p.k, MemSpace::Global, 2 * ksteps);
        t.warp.bmma_same_acc_ops = ksteps; // same c_frag accumulator
        attach_output(&mut t, mode, 1);
        attach_footprints(&mut t, p, mode);
        match mode {
            IoMode::General => with_general_io(vec![t], p),
            IoMode::BnnSpecific => vec![t],
        }
    }
}

// ---------------------------------------------------------------------------
// Design-2: 128-bit vectorized loads + shared-memory staging (Listing 4)
// ---------------------------------------------------------------------------

/// Design-2 (`bmma128`): a representative warp stages 4096-bit segments
/// of A and B into shared memory with LDG.E.128, then 16 warps run WMMA
/// from shared (load_matrix_sync is ~5x faster there, §4.1).
pub struct Design2;

impl BmmScheme for Design2 {
    fn name(&self) -> &'static str {
        "bmma128"
    }

    fn uses_tensorcores(&self) -> bool {
        true
    }

    fn supports(&self, p: BmmProblem, _mode: IoMode) -> bool {
        p.m % 128 == 0 && p.n % 128 == 0 && p.k % 128 == 0
    }

    fn compute(&self, a: &BitMatrix, b: &BitMatrix) -> Vec<i32> {
        let (m, n, k) = (a.rows, b.cols, a.cols);
        let mut out = vec![0i32; m * n];
        let kw = k / 32;
        // CTA loop: 128x128 output tile; k-steps of 128 bits staged to
        // "shared" (modeled by slicing; numerics identical)
        for bm in (0..m).step_by(128) {
            for bn in (0..n).step_by(128) {
                for ks in (0..kw).step_by(4) {
                    let kend = (ks + 4).min(kw);
                    // 16 warps: warp w owns rows bm+8w..bm+8w+8, all cols
                    for w in 0..16 {
                        let r0 = bm + 8 * w;
                        let a_rows: Vec<&[u32]> =
                            (0..8).map(|r| &a.line(r0 + r)[ks..kend]).collect();
                        for ct in 0..16 {
                            let c0 = bn + 8 * ct;
                            let b_cols: Vec<&[u32]> =
                                (0..8).map(|c| &b.line(c0 + c)[ks..kend]).collect();
                            tile_mma(&mut out, n, r0, c0, &a_rows, &b_cols);
                        }
                    }
                }
            }
        }
        out
    }

    fn traces(&self, p: BmmProblem, mode: IoMode) -> Vec<KernelTrace> {
        let mut t = KernelTrace::new("bmma128");
        t.warps_per_cta = 16; // Listing 4: 512-thread CTAs
        t.grid_ctas = ((p.m / 128) * (p.n / 128)).max(1);
        t.smem_per_cta = 4096; // As + Bs double buffers
        let ksteps = p.k / 128;
        // staging: per CTA per step 2 x 2KB via LDG.E.128, split across warps
        t.warp.bulk_load_bytes = ksteps * 4096 / 16;
        t.warp.shared_store_bytes = ksteps * 4096 / 16; // written into As/Bs
        // per warp per step: 1 A-strip + 16 B tiles from shared (compact,
        // ldm = 128), 16 bmma into 16 distinct accumulators (pipelined)
        t.warp.load_tiles(128, MemSpace::Shared, ksteps * 17);
        t.warp.bmma_ops = ksteps * 16;
        t.warp.cta_syncs = 2 * ksteps;
        // swizzled staging keeps one wave's panels L2-resident
        t.wave_bytes_per_cta = (2 * 128 * p.k / 8) as f64;
        attach_output(&mut t, mode, 16);
        attach_footprints(&mut t, p, mode);
        match mode {
            IoMode::General => with_general_io(vec![t], p),
            IoMode::BnnSpecific => vec![t],
        }
    }
}

// ---------------------------------------------------------------------------
// Design-3: FSB fixed-stride format (Listing 5)
// ---------------------------------------------------------------------------

/// Design-3 (`bmmafmt`): operands pre-converted to the FSB 128x8-bit
/// tile format so every global load_matrix_sync runs at the fast fixed
/// stride ldm = 128; output binarization fused via __ballot in the
/// BNN-specific protocol.
pub struct Design3;

impl BmmScheme for Design3 {
    fn name(&self) -> &'static str {
        "bmmafmt"
    }

    fn uses_tensorcores(&self) -> bool {
        true
    }

    fn compute(&self, a: &BitMatrix, b: &BitMatrix) -> Vec<i32> {
        // genuinely run from the FSB image (so the format conversion is
        // on the tested path)
        let fa = FsbMatrix::from_bitmatrix(a);
        let fb = FsbMatrix::from_bitmatrix(b);
        let (m, n, k) = (a.rows, b.cols, a.cols);
        let mut out = vec![0i32; m * n];
        let ktiles = k.div_ceil(fsb::BW);
        for ty in 0..m.div_ceil(fsb::BH) {
            for tb in 0..n.div_ceil(fsb::BH) {
                for kt in 0..ktiles {
                    let a_rows: Vec<&[u32]> =
                        (0..8).map(|r| fa.tile_row(ty, kt, r)).collect();
                    let b_cols: Vec<&[u32]> =
                        (0..8).map(|c| fb.tile_row(tb, kt, c)).collect();
                    // logical bits beyond k are zero in BOTH operands, so
                    // xor contributes 0 and Eq 2 pads cancel:
                    // (128-pad zeros) xor (zeros) = 0 disagreements, and
                    // tile_mma uses full 128-bit rows; compensate length.
                    tile_mma_padaware(
                        &mut out, n, ty * 8, tb * 8, &a_rows, &b_cols, k, kt,
                    );
                }
            }
        }
        out
    }

    fn traces(&self, p: BmmProblem, mode: IoMode) -> Vec<KernelTrace> {
        let mut t = KernelTrace::new("bmmafmt");
        let warps = (p.m / 8) * (p.n / 8);
        t.warps_per_cta = 2;
        t.grid_ctas = warps.div_ceil(2).max(1);
        let ksteps = p.k / 128;
        // the whole point: fixed ldm = 128 regardless of matrix width
        t.warp.load_tiles(128, MemSpace::Global, 2 * ksteps);
        t.warp.bmma_same_acc_ops = ksteps;
        attach_output(&mut t, mode, 1);
        attach_footprints(&mut t, p, mode);
        match mode {
            IoMode::General => with_general_io(vec![t], p),
            IoMode::BnnSpecific => vec![t],
        }
    }
}

/// Like `tile_mma` but aware that the last K tile may be padded: FSB pad
/// bits are 0 in both operands (xor = 0), which *undercounts* Eq 2's n
/// term; use the true remaining bit count instead of 128.
#[inline]
fn tile_mma_padaware(
    out: &mut [i32],
    n: usize,
    row0: usize,
    col0: usize,
    a_rows: &[&[u32]],
    b_cols: &[&[u32]],
    k: usize,
    kt: usize,
) {
    let bits_before = kt * fsb::BW;
    let bits_here = (k - bits_before).min(fsb::BW);
    for (ri, ar) in a_rows.iter().enumerate() {
        let r = row0 + ri;
        if r * n >= out.len() {
            break;
        }
        for (ci, bc) in b_cols.iter().enumerate() {
            let c = col0 + ci;
            if c >= n {
                break;
            }
            let mut p = 0u32;
            for (x, y) in ar.iter().zip(bc.iter()) {
                p += (x ^ y).count_ones();
            }
            out[r * n + c] += bits_here as i32 - 2 * p as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::Layout;
    use crate::kernels::bmm::naive_ref;
    use crate::sim::{Engine, RTX2080TI};
    use crate::util::Rng;

    #[test]
    fn design3_ldm_always_128() {
        for p in [BmmProblem::square(1024), BmmProblem::square(8192)] {
            let traces = Design3.traces(p, IoMode::BnnSpecific);
            for tr in &traces {
                for &(ldm, _, _) in &tr.warp.tile_loads {
                    assert_eq!(ldm, 128);
                }
            }
        }
    }

    #[test]
    fn design1_ldm_tracks_width() {
        let p = BmmProblem::square(2048);
        let traces = Design1.traces(p, IoMode::BnnSpecific);
        assert_eq!(traces[0].warp.tile_loads[0].0, 2048);
    }

    #[test]
    fn design2_beats_design1() {
        // §7.2 (II): "Design-2 is always better than Design-1" (at the
        // sub-1K end both are launch-overhead bound and tie in our model)
        let e = Engine::new(&RTX2080TI);
        for n in [1024usize, 2048, 4096, 8192] {
            let p = BmmProblem::square(n);
            let d1 = super::super::simulate(&e, &Design1, p, IoMode::General);
            let d2 = super::super::simulate(&e, &Design2, p, IoMode::General);
            assert!(d2 < d1, "n={n}: d2 {d2} !< d1 {d1}");
        }
    }

    #[test]
    fn fsb_compute_handles_unaligned_k() {
        // k = 192 exercises the pad-aware tail tile
        let mut rng = Rng::new(3);
        let a = BitMatrix::random(16, 192, Layout::RowMajor, &mut rng);
        let b = BitMatrix::random(192, 16, Layout::ColMajor, &mut rng);
        assert_eq!(Design3.compute(&a, &b), naive_ref(&a, &b));
    }
}
