//! BConv lowering onto the blocked u64 BMM via bit-im2row.
//!
//! Each output sample `(op, oq, ni)` becomes one im2row line: the k*k
//! input taps concatenated tap-by-tap, each tap padded to whole u64
//! words (`tap_words`).  Out-of-bounds taps are written as all-zero
//! words, so the whole line multiplies against a filter line with ONE
//! full-length popcount — and the paper's exclude-amended padding is
//! restored afterwards with a per-tap filter popcount correction:
//!
//! ```text
//! P          = popc(line ^ filter)            (what the BMM computes)
//! popc_valid = P - sum_{invalid taps} popc(filter_tap)
//! v          = c * valid_taps - 2 * popc_valid      (Eq 2, amended)
//! ```
//!
//! All quantities are exact integers, so the result is bit-identical
//! to `kernels::bconv::naive_ref` / `BconvDesign1` for every shape.
//!
//! The input slice layout is the executor's HWNC arena layout,
//! `((i*hw + j)*batch + ni) * wi` u32 words — which is exactly
//! `BitTensor4`'s HWNC storage, so both callers share one code path.

use crate::bitops::pack64::{self, words64};
use crate::bitops::{BitTensor4, TensorLayout};
use crate::kernels::bconv::BconvProblem;
use crate::util::threadpool::{scoped_chunks, scoped_chunks_numa, NumaTopology};

use super::bmm;

/// Filter prepared for the fastpath: one u64 line per output channel
/// (taps concatenated in (r, s) order, each padded to `tap_words`),
/// plus per-tap popcounts for the excluded-padding correction.
#[derive(Clone, Debug)]
pub struct FastConvFilter {
    pub o: usize,
    pub k: usize,
    pub c: usize,
    /// u64 words per tap: `words64(ceil(c/32))`
    pub tap_words: usize,
    /// u64 words per filter line: `k*k*tap_words`
    pub row_words: usize,
    /// `o` lines x `row_words` words
    pub data: Vec<u64>,
    /// `popc(filter tap)` indexed `[(r*k + s)*o + oi]`
    pub tap_popc: Vec<u32>,
}

impl FastConvFilter {
    /// Repack a KKOC packed filter into fastpath lines.
    pub fn prepare(filter: &BitTensor4) -> FastConvFilter {
        assert_eq!(filter.layout, TensorLayout::Kkoc);
        let [kh, kw, o, c] = filter.dims;
        assert_eq!(kh, kw, "square filters only");
        let k = kh;
        let wi = filter.words_inner;
        let tap_words = words64(wi);
        let row_words = k * k * tap_words;
        let mut data = vec![0u64; o * row_words];
        let mut tap_popc = vec![0u32; k * k * o];
        for r in 0..k {
            for s in 0..k {
                let tap = r * k + s;
                for oi in 0..o {
                    let src = filter.inner(r, s, oi);
                    let dst = &mut data
                        [oi * row_words + tap * tap_words..][..tap_words];
                    pack64::repack64_into(src, dst);
                    tap_popc[tap * o + oi] =
                        src.iter().map(|w| w.count_ones()).sum();
                }
            }
        }
        FastConvFilter { o, k, c, tap_words, row_words, data, tap_popc }
    }
}

/// u64 words of one im2row line for problem `p`.
pub fn row_words(p: BconvProblem) -> usize {
    p.k * p.k * words64(p.c.div_ceil(32))
}

/// im2row lines for problem `p` (one per output sample).
pub fn rows(p: BconvProblem) -> usize {
    p.out_hw() * p.out_hw() * p.n
}

/// Build the bit-im2row image of an HWNC packed input into `a64`
/// (`rows(p) x row_words(p)` u64 words), parallel over output pixels.
/// Out-of-bounds taps become zero words.
pub fn im2row_into(src: &[u32], p: BconvProblem, a64: &mut [u64], threads: usize) {
    let wi = p.c.div_ceil(32);
    let tap_words = words64(wi);
    let rw = p.k * p.k * tap_words;
    let ohw = p.out_hw();
    assert!(src.len() >= p.hw * p.hw * p.n * wi, "input buffer size");
    assert_eq!(a64.len(), ohw * ohw * p.n * rw, "im2row buffer size");
    // NUMA-sharded so each node's workers first-touch (and later
    // stream, via the matching popc band split) their own row range.
    scoped_chunks_numa(a64, p.n * rw, threads, NumaTopology::global(), |pix, lines| {
        let (op, oq) = (pix / ohw, pix % ohw);
        for r in 0..p.k {
            for s in 0..p.k {
                let tap = r * p.k + s;
                let i = (op * p.stride + r) as isize - p.pad as isize;
                let j = (oq * p.stride + s) as isize - p.pad as isize;
                let valid = i >= 0
                    && i < p.hw as isize
                    && j >= 0
                    && j < p.hw as isize;
                for ni in 0..p.n {
                    let dst = &mut lines[ni * rw + tap * tap_words..][..tap_words];
                    if valid {
                        let base =
                            ((i as usize * p.hw + j as usize) * p.n + ni) * wi;
                        pack64::repack64_into(&src[base..base + wi], dst);
                    } else {
                        dst.fill(0);
                    }
                }
            }
        }
    });
}

/// Full fastpath bconv: im2row + blocked BMM + excluded-padding
/// correction.  Output layout `((op*ohw + oq)*n + ni)*o + oi`, exactly
/// `kernels::bconv::naive_ref`.  `a64` is caller-provided scratch of
/// `rows(p) * row_words(p)` words (the executor's arena slice).
pub fn bconv_into(
    src: &[u32],
    p: BconvProblem,
    f: &FastConvFilter,
    a64: &mut [u64],
    out: &mut [i32],
    threads: usize,
) {
    assert_eq!(f.c, p.c, "filter channels");
    assert_eq!(f.k, p.k, "filter extent");
    assert_eq!(f.o, p.o, "output channels");
    assert!(p.k * p.k <= MAX_TAPS, "filter extent over fastpath limit");
    let ohw = p.out_hw();
    let m = ohw * ohw * p.n;
    assert_eq!(out.len(), m * p.o, "output buffer size");
    im2row_into(src, p, a64, threads);
    bmm::popc_lines(a64, &f.data, f.row_words, m, p.o, out, threads);
    amend_excluded(out, p, f, threads);
}

/// [`bconv_into`] with the BMM inner product dispatched through a
/// caller-supplied dot kernel (the SIMD backend's `PopcountEngine`):
/// same bit-im2row lowering, same exclude-amended correction,
/// bit-identical output for any exact-popcount `dot`.
pub fn bconv_into_with<D>(
    src: &[u32],
    p: BconvProblem,
    f: &FastConvFilter,
    a64: &mut [u64],
    out: &mut [i32],
    threads: usize,
    dot: &D,
) where
    D: Fn(&[u64], &[u64]) -> u32 + Sync,
{
    assert_eq!(f.c, p.c, "filter channels");
    assert_eq!(f.k, p.k, "filter extent");
    assert_eq!(f.o, p.o, "output channels");
    assert!(p.k * p.k <= MAX_TAPS, "filter extent over fastpath limit");
    let ohw = p.out_hw();
    let m = ohw * ohw * p.n;
    assert_eq!(out.len(), m * p.o, "output buffer size");
    im2row_into(src, p, a64, threads);
    bmm::popc_lines_with(a64, &f.data, f.row_words, m, p.o, out, threads, dot);
    amend_excluded(out, p, f, threads);
}

/// Restore the exclude-amended Eq 2 per output pixel after the raw
/// popcount BMM (shared by the fastpath and SIMD backends).
fn amend_excluded(out: &mut [i32], p: BconvProblem, f: &FastConvFilter, threads: usize) {
    let ohw = p.out_hw();
    let taps = p.k * p.k;
    scoped_chunks(out, p.n * p.o, threads, |pix, seg| {
        let (op, oq) = (pix / ohw, pix % ohw);
        let mut inv = [0usize; MAX_TAPS];
        let mut ninv = 0usize;
        for r in 0..p.k {
            for s in 0..p.k {
                let i = (op * p.stride + r) as isize - p.pad as isize;
                let j = (oq * p.stride + s) as isize - p.pad as isize;
                if i < 0 || i >= p.hw as isize || j < 0 || j >= p.hw as isize {
                    inv[ninv] = r * p.k + s;
                    ninv += 1;
                }
            }
        }
        let n_valid = (p.c * (taps - ninv)) as i32;
        for ni in 0..p.n {
            let row = &mut seg[ni * p.o..(ni + 1) * p.o];
            if ninv == 0 {
                for v in row.iter_mut() {
                    *v = n_valid - 2 * *v;
                }
            } else {
                for (oi, v) in row.iter_mut().enumerate() {
                    let mut corr = 0i32;
                    for &tap in &inv[..ninv] {
                        corr += f.tap_popc[tap * p.o + oi] as i32;
                    }
                    *v = n_valid - 2 * (*v - corr);
                }
            }
        }
    });
}

/// Largest supported filter tap count (k*k); BinConv filters in the
/// Table-5 models are at most 5x5.
pub const MAX_TAPS: usize = 32;

/// Allocating convenience wrapper (the naive fastpath forward, tests).
pub fn bconv(
    input: &BitTensor4,
    filter: &BitTensor4,
    p: BconvProblem,
    threads: usize,
) -> Vec<i32> {
    assert_eq!(input.layout, TensorLayout::Hwnc);
    assert_eq!(input.dims, [p.hw, p.hw, p.n, p.c], "input dims");
    let f = FastConvFilter::prepare(filter);
    let mut a64 = vec![0u64; rows(p) * row_words(p)];
    let mut out = vec![0i32; rows(p) * p.o];
    bconv_into(&input.data, p, &f, &mut a64, &mut out, threads);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::bconv::naive_ref;
    use crate::util::proptest::run_cases;
    use crate::util::Rng;

    fn rand_case(rng: &mut Rng, p: BconvProblem) -> (BitTensor4, BitTensor4) {
        let input =
            BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, rng);
        let filter =
            BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, rng);
        (input, filter)
    }

    #[test]
    fn matches_naive_ref_with_padding() {
        let mut rng = Rng::new(81);
        for p in [
            BconvProblem { hw: 6, n: 8, c: 128, o: 8, k: 3, stride: 1, pad: 1 },
            BconvProblem { hw: 8, n: 4, c: 96, o: 16, k: 3, stride: 2, pad: 1 },
            BconvProblem { hw: 5, n: 3, c: 40, o: 7, k: 3, stride: 1, pad: 0 },
            BconvProblem { hw: 9, n: 2, c: 64, o: 5, k: 5, stride: 1, pad: 2 },
        ] {
            let (input, filter) = rand_case(&mut rng, p);
            assert_eq!(
                bconv(&input, &filter, p, 2),
                naive_ref(&input, &filter, p),
                "{p:?}"
            );
        }
    }

    #[test]
    fn random_odd_channel_widths() {
        run_cases(82, 25, |rng| {
            let p = BconvProblem {
                hw: 3 + rng.gen_range(5),
                n: 1 + rng.gen_range(6),
                c: 1 + rng.gen_range(150),
                o: 1 + rng.gen_range(20),
                k: 3,
                stride: 1,
                pad: 1,
            };
            let (input, filter) = rand_case(rng, p);
            assert_eq!(
                bconv(&input, &filter, p, 1),
                naive_ref(&input, &filter, p),
                "{p:?}"
            );
        });
    }

    #[test]
    fn serial_and_parallel_agree() {
        let mut rng = Rng::new(83);
        let p = BconvProblem { hw: 8, n: 8, c: 64, o: 16, k: 3, stride: 1, pad: 1 };
        let (input, filter) = rand_case(&mut rng, p);
        assert_eq!(bconv(&input, &filter, p, 1), bconv(&input, &filter, p, 4));
    }

    #[test]
    fn tap_popc_counts_plus_ones() {
        let mut rng = Rng::new(84);
        let filter = BitTensor4::random([3, 3, 4, 40], TensorLayout::Kkoc, &mut rng);
        let f = FastConvFilter::prepare(&filter);
        for r in 0..3 {
            for s in 0..3 {
                for oi in 0..4 {
                    let want = (0..40).filter(|&ci| filter.get(r, s, oi, ci)).count();
                    assert_eq!(f.tap_popc[(r * 3 + s) * 4 + oi] as usize, want);
                }
            }
        }
    }
}
