//! The host fastpath backend: blocked u64 XNOR-popcount kernels.
//!
//! The paper's lesson (§4–5) is that BNN throughput is decided by
//! bit-level data layout and memory stride; PhoneBit shows the same
//! XNOR-popcount kernels dominate end-to-end latency on CPU-class
//! hardware.  This module is the repo's genuinely fast *host* path —
//! the backend `nn::cost::Scheme::Fastpath` selects and the engine
//! executor routes to:
//!
//! * [`bmm`] — cache-blocked (`MC x NC x KC`), 4x4-register-tiled
//!   XNOR-popcount BMM over u64-repacked operands
//!   (`bitops::pack64`), row-parallel over contiguous scoped-thread
//!   row bands;
//! * [`bconv`] — the convolution lowering: bit-im2row (out-of-bounds
//!   taps as zero words) feeding the same blocked BMM, with a per-tap
//!   filter-popcount correction restoring the paper's exclude-amended
//!   padding.
//!
//! Every kernel is exact integer arithmetic, bit-identical to the
//! naive Eq-2 references (`kernels::bmm::naive_ref`,
//! `kernels::bconv::naive_ref`) and the Design-1/2/3 scheme computes —
//! asserted by `tests/backend_equivalence.rs` (every registered
//! backend) and `tests/fastpath_equivalence.rs`.  Unlike the Table-3/4
//! schemes there is no GPU `KernelTrace` face: the cost model is the
//! analytic host model in `kernels::backends::fastpath` (its `host`
//! constants re-export as `nn::cost::host`), wired through the
//! `KernelBackend` registry.

pub mod bconv;
pub mod bmm;

pub use bconv::FastConvFilter;
