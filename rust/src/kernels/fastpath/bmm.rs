//! Cache-blocked, register-tiled u64 XNOR-popcount BMM.
//!
//! Problem convention matches `kernels::bmm::naive_ref`: `a` holds `m`
//! packed lines of `k` bits (rows of A), `b` holds `n` packed lines of
//! `k` bits (columns of B == rows of B^T), output is `m x n` row-major
//! i32 Eq-2 values.  All arithmetic is exact integer popcounting, so
//! the result is bit-identical to the naive reference regardless of
//! blocking order.
//!
//! Blocking: `MC x NC` output panels walked with a `KC`-word K loop
//! (operand panels stay L1/L2 resident), 4x4 register accumulator
//! tiles inside a panel (each loaded A word is XORed against four B
//! words and vice versa), and `chunks_exact` inner loops that the
//! compiler autovectorizes.  Row-parallel dispatch hands each scoped
//! worker one contiguous multi-row band, so the B panel streams once
//! per band while the MC/NC/KC loops tile within it.

use crate::bitops::pack64::{xor_popc64, BitMatrix64};
use crate::bitops::{BitMatrix, Layout};
use crate::util::threadpool::{scoped_bands_numa, NumaTopology};

/// Output-row block (A panel height).
pub const MC: usize = 64;
/// Output-column block (B panel height).
pub const NC: usize = 64;
/// K-loop block in u64 words (16 Kbit of operand per line).
pub const KC: usize = 256;

/// 4x4 register tile: accumulate popc(a_r ^ b_t) for four A lines
/// against four B lines over one K block.  All eight slices must have
/// equal length (sliced by the caller from the same K block).
#[inline]
#[allow(clippy::too_many_arguments)]
fn tile4x4(
    a0: &[u64],
    a1: &[u64],
    a2: &[u64],
    a3: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
    acc: &mut [[u32; 4]; 4],
) {
    let len = a0.len();
    let (a1, a2, a3) = (&a1[..len], &a2[..len], &a3[..len]);
    let (b0, b1, b2, b3) = (&b0[..len], &b1[..len], &b2[..len], &b3[..len]);
    for w in 0..len {
        let av = [a0[w], a1[w], a2[w], a3[w]];
        let bv = [b0[w], b1[w], b2[w], b3[w]];
        for (r, &x) in av.iter().enumerate() {
            acc[r][0] += (x ^ bv[0]).count_ones();
            acc[r][1] += (x ^ bv[1]).count_ones();
            acc[r][2] += (x ^ bv[2]).count_ones();
            acc[r][3] += (x ^ bv[3]).count_ones();
        }
    }
}

/// One MC x NC x KC block of the popcount accumulation, 4x4-tiled with
/// scalar edge cleanup.  `out` covers the whole `mb x n` band.
#[allow(clippy::too_many_arguments)]
fn popc_block(
    a: &[u64],
    b: &[u64],
    wk: usize,
    (i0, ib): (usize, usize),
    (j0, jb): (usize, usize),
    (k0, kb): (usize, usize),
    n: usize,
    out: &mut [i32],
) {
    let mut i = i0;
    while i + 4 <= ib {
        let a0 = &a[i * wk + k0..i * wk + kb];
        let a1 = &a[(i + 1) * wk + k0..(i + 1) * wk + kb];
        let a2 = &a[(i + 2) * wk + k0..(i + 2) * wk + kb];
        let a3 = &a[(i + 3) * wk + k0..(i + 3) * wk + kb];
        let mut j = j0;
        while j + 4 <= jb {
            let b0 = &b[j * wk + k0..j * wk + kb];
            let b1 = &b[(j + 1) * wk + k0..(j + 1) * wk + kb];
            let b2 = &b[(j + 2) * wk + k0..(j + 2) * wk + kb];
            let b3 = &b[(j + 3) * wk + k0..(j + 3) * wk + kb];
            let mut acc = [[0u32; 4]; 4];
            tile4x4(a0, a1, a2, a3, b0, b1, b2, b3, &mut acc);
            for (r, row) in acc.iter().enumerate() {
                let base = (i + r) * n + j;
                out[base] += row[0] as i32;
                out[base + 1] += row[1] as i32;
                out[base + 2] += row[2] as i32;
                out[base + 3] += row[3] as i32;
            }
            j += 4;
        }
        while j < jb {
            let bj = &b[j * wk + k0..j * wk + kb];
            for (r, ar) in [a0, a1, a2, a3].into_iter().enumerate() {
                out[(i + r) * n + j] += xor_popc64(ar, bj) as i32;
            }
            j += 1;
        }
        i += 4;
    }
    while i < ib {
        let ar = &a[i * wk + k0..i * wk + kb];
        for j in j0..jb {
            let bj = &b[j * wk + k0..j * wk + kb];
            out[i * n + j] += xor_popc64(ar, bj) as i32;
        }
        i += 1;
    }
}

/// Serial popcount accumulation over a band of `mb` A lines: walks
/// MC x NC x KC blocks over the band.  `out` must be zeroed first.
fn popc_band(a: &[u64], b: &[u64], wk: usize, mb: usize, n: usize, out: &mut [i32]) {
    debug_assert_eq!(a.len(), mb * wk);
    debug_assert_eq!(b.len(), n * wk);
    debug_assert_eq!(out.len(), mb * n);
    for i0 in (0..mb).step_by(MC) {
        let ib = (i0 + MC).min(mb);
        for j0 in (0..n).step_by(NC) {
            let jb = (j0 + NC).min(n);
            for k0 in (0..wk).step_by(KC) {
                let kb = (k0 + KC).min(wk);
                popc_block(a, b, wk, (i0, ib), (j0, jb), (k0, kb), n, out);
            }
        }
    }
}

/// Row-parallel popcount accumulation: `out[i*n + j] = popc(a_i ^ b_j)`.
/// `a`: `m` lines of `wk` u64 words, `b`: `n` lines of `wk` words.
pub fn popc_lines(
    a: &[u64],
    b: &[u64],
    wk: usize,
    m: usize,
    n: usize,
    out: &mut [i32],
    threads: usize,
) {
    assert_eq!(a.len(), m * wk, "A line buffer size");
    assert_eq!(b.len(), n * wk, "B line buffer size");
    assert_eq!(out.len(), m * n, "output size");
    out.fill(0);
    if m == 0 || n == 0 || wk == 0 {
        return;
    }
    // One contiguous multi-row band per worker (multiple of 4 rows so
    // the 4x4 tile path stays hot), handed to popc_band whole: the MC
    // loop tiles inside the band and the B panel streams once per band,
    // not once per 4 rows.  Bands are split NUMA-node-proportionally
    // (scoped_bands_numa; flat split on single-node hosts) so each
    // node's workers stream the A rows they first-touched.  The up-to-3
    // leftover rows of a non-multiple-of-4 m run scalar at the end.
    let m4 = m / 4 * 4;
    if m4 > 0 {
        let groups = m4 / 4;
        let t = threads.max(1).min(groups);
        if t <= 1 {
            popc_band(&a[..m4 * wk], b, wk, m4, n, &mut out[..m4 * n]);
        } else {
            scoped_bands_numa(&mut out[..m4 * n], 4 * n, t, NumaTopology::global(), |g0, band| {
                let rows = band.len() / n;
                let r0 = g0 * 4;
                popc_band(&a[r0 * wk..(r0 + rows) * wk], b, wk, rows, n, band);
            });
        }
    }
    if m4 < m {
        popc_band(&a[m4 * wk..], b, wk, m - m4, n, &mut out[m4 * n..]);
    }
}

/// [`popc_block`] with the line inner product delegated to a caller
/// supplied dot kernel: plain row x column loops over the K block, no
/// 4x4 word interleave — the SIMD engines unroll lanes *inside* `dot`,
/// so interleaving words across lines here would only defeat them.
#[allow(clippy::too_many_arguments)]
fn popc_block_with<D>(
    a: &[u64],
    b: &[u64],
    wk: usize,
    (i0, ib): (usize, usize),
    (j0, jb): (usize, usize),
    (k0, kb): (usize, usize),
    n: usize,
    out: &mut [i32],
    dot: &D,
) where
    D: Fn(&[u64], &[u64]) -> u32,
{
    for i in i0..ib {
        let ar = &a[i * wk + k0..i * wk + kb];
        for j in j0..jb {
            let bj = &b[j * wk + k0..j * wk + kb];
            out[i * n + j] += dot(ar, bj) as i32;
        }
    }
}

/// [`popc_band`] with a caller-supplied dot kernel: the same
/// MC x NC x KC cache-blocked walk over one band.
fn popc_band_with<D>(a: &[u64], b: &[u64], wk: usize, mb: usize, n: usize, out: &mut [i32], dot: &D)
where
    D: Fn(&[u64], &[u64]) -> u32,
{
    debug_assert_eq!(a.len(), mb * wk);
    debug_assert_eq!(b.len(), n * wk);
    debug_assert_eq!(out.len(), mb * n);
    for i0 in (0..mb).step_by(MC) {
        let ib = (i0 + MC).min(mb);
        for j0 in (0..n).step_by(NC) {
            let jb = (j0 + NC).min(n);
            for k0 in (0..wk).step_by(KC) {
                let kb = (k0 + KC).min(wk);
                popc_block_with(a, b, wk, (i0, ib), (j0, jb), (k0, kb), n, out, dot);
            }
        }
    }
}

/// [`popc_lines`] with the KC-word inner product dispatched through a
/// caller-supplied dot kernel (the SIMD backend's `PopcountEngine`):
/// same blocking, same NUMA-sharded row bands, bit-identical output
/// for any exact-popcount `dot`.
#[allow(clippy::too_many_arguments)]
pub fn popc_lines_with<D>(
    a: &[u64],
    b: &[u64],
    wk: usize,
    m: usize,
    n: usize,
    out: &mut [i32],
    threads: usize,
    dot: &D,
) where
    D: Fn(&[u64], &[u64]) -> u32 + Sync,
{
    assert_eq!(a.len(), m * wk, "A line buffer size");
    assert_eq!(b.len(), n * wk, "B line buffer size");
    assert_eq!(out.len(), m * n, "output size");
    out.fill(0);
    if m == 0 || n == 0 || wk == 0 {
        return;
    }
    let t = threads.max(1).min(m);
    if t <= 1 {
        popc_band_with(a, b, wk, m, n, out, dot);
    } else {
        scoped_bands_numa(out, n, t, NumaTopology::global(), |r0, band| {
            let rows = band.len() / n;
            popc_band_with(&a[r0 * wk..(r0 + rows) * wk], b, wk, rows, n, band, dot);
        });
    }
}

/// [`dot_lines`] with a caller-supplied dot kernel: Eq-2 transform of
/// [`popc_lines_with`].
#[allow(clippy::too_many_arguments)]
pub fn dot_lines_with<D>(
    a: &[u64],
    b: &[u64],
    wk: usize,
    m: usize,
    n: usize,
    k_bits: usize,
    out: &mut [i32],
    threads: usize,
    dot: &D,
) where
    D: Fn(&[u64], &[u64]) -> u32 + Sync,
{
    popc_lines_with(a, b, wk, m, n, out, threads, dot);
    let k = k_bits as i32;
    for v in out.iter_mut() {
        *v = k - 2 * *v;
    }
}

/// Row-parallel Eq-2 BMM over packed u64 lines:
/// `out[i*n + j] = k_bits - 2*popc(a_i ^ b_j)`.
#[allow(clippy::too_many_arguments)]
pub fn dot_lines(
    a: &[u64],
    b: &[u64],
    wk: usize,
    m: usize,
    n: usize,
    k_bits: usize,
    out: &mut [i32],
    threads: usize,
) {
    popc_lines(a, b, wk, m, n, out, threads);
    let k = k_bits as i32;
    for v in out.iter_mut() {
        *v = k - 2 * *v;
    }
}

/// Eq-2 BMM on repacked operands: `a` (m x k) row-major, `b` (k x n)
/// column-major — the `kernels::bmm::naive_ref` convention.
pub fn bmm_into(a: &BitMatrix64, b: &BitMatrix64, out: &mut [i32], threads: usize) {
    assert_eq!(a.layout, Layout::RowMajor, "A must be row-major");
    assert_eq!(b.layout, Layout::ColMajor, "B must be column-major");
    assert_eq!(a.cols, b.rows, "inner dimensions");
    assert_eq!(
        a.words_per_line, b.words_per_line,
        "operands must pack the same K width"
    );
    dot_lines(
        &a.data,
        &b.data,
        a.words_per_line,
        a.rows,
        b.cols,
        a.cols,
        out,
        threads,
    );
}

/// Allocating convenience wrapper (tests / the naive fastpath forward):
/// repack + blocked multiply in one call.
pub fn bmm(a: &BitMatrix, b: &BitMatrix, threads: usize) -> Vec<i32> {
    let a64 = BitMatrix64::from_bitmatrix(a);
    let b64 = BitMatrix64::from_bitmatrix(b);
    let mut out = vec![0i32; a.rows * b.cols];
    bmm_into(&a64, &b64, &mut out, threads);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::bmm::naive_ref;
    use crate::util::proptest::run_cases;

    #[test]
    fn matches_naive_ref_on_random_shapes() {
        run_cases(71, 40, |rng| {
            let m = 1 + rng.gen_range(40);
            let n = 1 + rng.gen_range(40);
            let k = 1 + rng.gen_range(300);
            let a = BitMatrix::random(m, k, Layout::RowMajor, rng);
            let b = BitMatrix::random(k, n, Layout::ColMajor, rng);
            assert_eq!(bmm(&a, &b, 1), naive_ref(&a, &b), "{m}x{n}x{k}");
        });
    }

    #[test]
    fn serial_and_parallel_agree() {
        run_cases(72, 20, |rng| {
            let m = 1 + rng.gen_range(70);
            let n = 1 + rng.gen_range(70);
            let k = 1 + rng.gen_range(400);
            let a = BitMatrix::random(m, k, Layout::RowMajor, rng);
            let b = BitMatrix::random(k, n, Layout::ColMajor, rng);
            assert_eq!(bmm(&a, &b, 1), bmm(&a, &b, 4));
        });
    }

    #[test]
    fn blocking_boundaries_are_exact() {
        // shapes straddling MC/NC/KC edges
        let mut rng = crate::util::Rng::new(73);
        for (m, n, kw) in [
            (MC, NC, KC),
            (MC + 1, NC + 3, KC + 1),
            (MC - 1, NC - 1, KC - 1),
            (2 * MC + 5, NC + 1, 2),
        ] {
            let k = kw * 64;
            let a = BitMatrix::random(m, k, Layout::RowMajor, &mut rng);
            let b = BitMatrix::random(k, n, Layout::ColMajor, &mut rng);
            assert_eq!(bmm(&a, &b, 2), naive_ref(&a, &b), "{m}x{n}x{k}");
        }
    }

    #[test]
    fn degenerate_rows_and_cols() {
        let mut rng = crate::util::Rng::new(74);
        for (m, n, k) in [(1, 33, 97), (33, 1, 97), (1, 1, 1)] {
            let a = BitMatrix::random(m, k, Layout::RowMajor, &mut rng);
            let b = BitMatrix::random(k, n, Layout::ColMajor, &mut rng);
            assert_eq!(bmm(&a, &b, 3), naive_ref(&a, &b), "{m}x{n}x{k}");
        }
    }

    #[test]
    fn generic_dot_path_matches_tiled_path() {
        // popc_lines_with must agree with popc_lines for any exact dot
        // kernel; with xor_popc64 plugged in the two differ only in
        // blocking order, which exact popcounts cannot observe.
        run_cases(75, 25, |rng| {
            let m = 1 + rng.gen_range(70);
            let n = 1 + rng.gen_range(70);
            let k = 1 + rng.gen_range(400);
            let a = BitMatrix64::from_bitmatrix(&BitMatrix::random(m, k, Layout::RowMajor, rng));
            let b = BitMatrix64::from_bitmatrix(&BitMatrix::random(k, n, Layout::ColMajor, rng));
            let wk = a.words_per_line;
            let mut tiled = vec![0i32; m * n];
            popc_lines(&a.data, &b.data, wk, m, n, &mut tiled, 2);
            for threads in [1, 3] {
                let mut generic = vec![0i32; m * n];
                popc_lines_with(&a.data, &b.data, wk, m, n, &mut generic, threads, &xor_popc64);
                assert_eq!(generic, tiled, "{m}x{n}x{k} t{threads}");
            }
        });
    }
}
