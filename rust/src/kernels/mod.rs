//! Functional + cost-modeled implementations of every BMM and BConv
//! scheme in the paper's evaluation (Tables 3–4, Figs 16–23).
//!
//! Each scheme has two faces:
//!
//! * `compute(...)` — a bit-exact CPU implementation of the scheme's
//!   algorithm (all BMM schemes must agree with the naive Eq-2 product;
//!   all BConv schemes with the exclude-amended cross-correlation);
//! * `trace(...)`  — the scheme's `sim::KernelTrace`s (one per kernel
//!   launch), carrying the *actual* strides, staging, accumulator reuse
//!   and op mix of that design, from which the Turing timing model
//!   predicts cycles.
//!
//! IO modes mirror the paper's two test types: `General` (fp in / int
//! out: binarization of A and B is on the clock, §7.2 type 1) and
//! `BnnSpecific` (bit in / bit out: fused output binarization, type 2).
//!
//! `fastpath` is the odd one out: the blocked u64 *host* backend
//! (`Scheme::Fastpath`) — bit-identical compute, no GPU trace face.
//!
//! `backend` is the unifying layer above all of this: the
//! [`backend::KernelBackend`] trait (prepare / execute / cost faces)
//! and the [`backend::BackendRegistry`] that `nn::forward`,
//! `nn::cost`, and the engine dispatch through — one registration per
//! scheme instead of per-consumer `match` arms.  The builtin
//! implementations live in `backends`.

pub mod backend;
pub mod backends;
pub mod bconv;
pub mod bmm;
pub mod fastpath;
pub mod simd;

/// Which of the paper's two benchmark protocols a trace models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoMode {
    /// floats in, int32 out — includes binarize(A), binarize(B)
    General,
    /// packed bits in, packed bits out — includes fused binarize(C)
    BnnSpecific,
}

pub use backend::{BackendRegistry, ExecCtx, KernelBackend, PreparedConv, PreparedFc};
pub use bconv::{BconvProblem, BconvScheme};
pub use bmm::BmmScheme;
