//! The explicit-SIMD host backend (`Scheme::Simd`): same prepared
//! weight forms, cache blocking, and bit-im2row lowering as the
//! fastpath, with the KC-word inner product dispatched through a
//! [`PopcountEngine`] selected once at registry construction (runtime
//! feature detection, `TCBNN_SIMD` override).  The cost face is the
//! shared analytic host curve with engine-dependent word throughput,
//! so the planner and tuner treat the engine choice as a calibratable
//! coefficient, not a different model.

use anyhow::{ensure, Result};

use crate::bitops::pack64::{self, BitMatrix64};
use crate::bitops::{BitMatrix, BitTensor4};
use crate::kernels::backend::{ExecCtx, KernelBackend, PreparedConv, PreparedFc};
use crate::kernels::backends::fastpath::{analytic_host_secs, host as fastpath_host, HostRates};
use crate::kernels::bconv::BconvProblem;
use crate::kernels::fastpath::{self, FastConvFilter};
use crate::kernels::simd::PopcountEngine;
use crate::layout::LayoutKind;
use crate::nn::cost::{ResidualMode, Scheme};
use crate::nn::layer::{Dims, LayerSpec};
use crate::sim::{Engine, KernelTrace};

/// Calibrated host constants for the SIMD cost model.  FP, byte, and
/// dispatch rates are the fastpath's (same cores, same im2row and
/// streaming code); only the popcount word rate depends on the engine.
/// Seeds are conservative per-engine estimates — the tuner's
/// calibration run replaces them with fitted per-host values.
pub mod host {
    use crate::kernels::simd::PopcountEngine;

    /// Portable u64 `count_ones` through the generic (untiled) blocked
    /// path: slightly below the fastpath's 4x4-tiled 6.0e9.
    pub const PORTABLE_WORD_OPS_PER_SEC: f64 = 5.0e9;
    /// Hardware scalar `popcnt`, 4-word unroll.
    pub const AVX2_WORD_OPS_PER_SEC: f64 = 1.4e10;
    /// `vpopcntdq`, 8 words per instruction.
    pub const AVX512_WORD_OPS_PER_SEC: f64 = 2.8e10;
    /// NEON `cnt` + horizontal add, 16-word blocks.
    pub const NEON_WORD_OPS_PER_SEC: f64 = 1.1e10;

    /// Seed word throughput for `engine`.
    pub fn word_ops_per_sec(engine: PopcountEngine) -> f64 {
        match engine {
            PopcountEngine::Portable => PORTABLE_WORD_OPS_PER_SEC,
            PopcountEngine::Avx2 => AVX2_WORD_OPS_PER_SEC,
            PopcountEngine::Avx512 => AVX512_WORD_OPS_PER_SEC,
            PopcountEngine::Neon => NEON_WORD_OPS_PER_SEC,
        }
    }
}

/// The explicit-SIMD host backend.
pub struct SimdBackend {
    engine: PopcountEngine,
}

impl SimdBackend {
    /// Backend with the engine runtime detection (+ `TCBNN_SIMD`
    /// override) selects — what `BackendRegistry::builtin` registers.
    pub fn detect() -> SimdBackend {
        SimdBackend { engine: PopcountEngine::detect() }
    }

    /// Backend pinned to a specific engine.  The caller must only pass
    /// an [`available`](PopcountEngine::is_available) engine
    /// (asserted), which equivalence tests iterate explicitly.
    pub fn with_engine(engine: PopcountEngine) -> SimdBackend {
        assert!(engine.is_available(), "engine {} not available on this host", engine.name());
        SimdBackend { engine }
    }

    /// The engine this backend dispatches through.
    pub fn engine(&self) -> PopcountEngine {
        self.engine
    }
}

/// FC weights repacked to u64 lines once, off the request path — the
/// same prepared form as the fastpath; only the dot kernel differs.
struct SimdFc {
    w64: BitMatrix64,
    engine: PopcountEngine,
}

impl SimdFc {
    fn dot_lines(&self, rows: &[u64], batch: usize, ints: &mut [i32], threads: usize) {
        let engine = self.engine;
        let dot = move |x: &[u64], y: &[u64]| engine.xor_popc(x, y);
        fastpath::bmm::dot_lines_with(
            rows,
            &self.w64.data,
            self.w64.words_per_line,
            batch,
            self.w64.rows,
            self.w64.cols,
            ints,
            threads,
            &dot,
        );
    }
}

impl PreparedFc for SimdFc {
    fn scratch_words(&self, batch: usize) -> usize {
        batch * self.w64.words_per_line
    }

    /// Native operand form: u64 lines (shared with the fastpath, so
    /// planned `Blocked64` edges chain across the two host schemes).
    fn input_layout(&self) -> LayoutKind {
        LayoutKind::Blocked64
    }

    fn supports_input_layout(&self, layout: LayoutKind) -> bool {
        matches!(layout, LayoutKind::Row32 | LayoutKind::Blocked64)
    }

    fn bmm(&self, src: &[u32], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let wpl_in = self.w64.cols.div_ceil(32);
        let w64in = self.w64.words_per_line;
        debug_assert_eq!(pack64::words64(wpl_in), w64in, "weight repack width");
        assert!(src.len() >= batch * wpl_in, "input row buffer size");
        assert_eq!(ints.len(), batch * self.w64.rows, "dot staging size");
        let rows = &mut ctx.words64[..batch * w64in];
        for (ni, row) in rows.chunks_exact_mut(w64in).enumerate() {
            pack64::repack64_into(&src[ni * wpl_in..(ni + 1) * wpl_in], row);
        }
        self.dot_lines(rows, batch, ints, ctx.threads);
    }

    fn bmm64(&self, src64: &[u64], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let w64in = self.w64.words_per_line;
        assert!(src64.len() >= batch * w64in, "u64 input row buffer size");
        assert_eq!(ints.len(), batch * self.w64.rows, "dot staging size");
        self.dot_lines(&src64[..batch * w64in], batch, ints, ctx.threads);
    }
}

/// Conv filter in the fastpath's prepared u64 form; the lowering and
/// correction are shared, the BMM dot kernel is the engine's.
struct SimdConv {
    f: FastConvFilter,
    engine: PopcountEngine,
}

impl PreparedConv for SimdConv {
    fn scratch_words(&self, p: BconvProblem) -> usize {
        fastpath::bconv::rows(p) * self.f.row_words
    }

    fn bconv(&self, src: &[u32], p: BconvProblem, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let need = fastpath::bconv::rows(p) * self.f.row_words;
        let engine = self.engine;
        let dot = move |x: &[u64], y: &[u64]| engine.xor_popc(x, y);
        fastpath::bconv::bconv_into_with(
            src,
            p,
            &self.f,
            &mut ctx.words64[..need],
            ints,
            ctx.threads,
            &dot,
        );
    }
}

impl KernelBackend for SimdBackend {
    fn scheme(&self) -> Scheme {
        Scheme::Simd
    }

    /// Same layout faces as the fastpath: FC layers natively consume
    /// and emit `Blocked64`, so the (scheme, layout) DP chains
    /// consecutive host FC layers with no repack edges — including
    /// mixed fastpath/SIMD chains.
    fn preferred_input_layout(&self, layer: &LayerSpec) -> LayoutKind {
        match layer {
            LayerSpec::BinFc { .. } | LayerSpec::FinalFc { .. } => LayoutKind::Blocked64,
            _ => LayoutKind::Row32,
        }
    }

    fn output_layout(&self, layer: &LayerSpec) -> LayoutKind {
        match layer {
            LayerSpec::BinFc { .. } => LayoutKind::Blocked64,
            _ => LayoutKind::Row32,
        }
    }

    fn prepare_fc(&self, w: &BitMatrix) -> Result<Box<dyn PreparedFc>> {
        Ok(Box::new(SimdFc { w64: BitMatrix64::from_bitmatrix(w), engine: self.engine }))
    }

    fn prepare_conv(
        &self,
        filter: &BitTensor4,
        p: BconvProblem,
    ) -> Result<Box<dyn PreparedConv>> {
        ensure!(
            p.k * p.k <= fastpath::bconv::MAX_TAPS,
            "{}x{} filter exceeds the host tap limit ({} taps)",
            p.k,
            p.k,
            fastpath::bconv::MAX_TAPS
        );
        Ok(Box::new(SimdConv { f: FastConvFilter::prepare(filter), engine: self.engine }))
    }

    /// Host backend: no GPU trace face.
    fn layer_traces(
        &self,
        _layer: &LayerSpec,
        _dims: Dims,
        _batch: usize,
        _residual: ResidualMode,
        _model_has_residuals: bool,
    ) -> Vec<KernelTrace> {
        Vec::new()
    }

    fn layer_secs(
        &self,
        _engine: &Engine,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> f64 {
        let rates = HostRates {
            word_ops_per_sec: host::word_ops_per_sec(self.engine),
            fp_ops_per_sec: fastpath_host::FP_OPS_PER_SEC,
            bytes_per_sec: fastpath_host::BYTES_PER_SEC,
            dispatch_secs: fastpath_host::DISPATCH_SECS,
        };
        analytic_host_secs(&rates, layer, dims, batch, residual, model_has_residuals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_respects_the_available_contract() {
        let b = SimdBackend::detect();
        assert!(b.engine().is_available());
        assert_eq!(b.scheme(), Scheme::Simd);
    }

    #[test]
    fn with_engine_pins_and_every_available_engine_constructs() {
        for e in PopcountEngine::available() {
            assert_eq!(SimdBackend::with_engine(e).engine(), e);
        }
    }

    #[test]
    #[should_panic(expected = "not available")]
    fn with_engine_rejects_unavailable_engines() {
        // at least one of the vector engines is foreign on any host
        let foreign = [PopcountEngine::Avx512, PopcountEngine::Neon]
            .into_iter()
            .find(|e| !e.is_available())
            .expect("some engine must be unavailable");
        let _ = SimdBackend::with_engine(foreign);
    }

    #[test]
    fn cost_face_scales_with_the_engine_word_rate() {
        use crate::sim::RTX2080TI;
        let eng = Engine::new(&RTX2080TI);
        let layer = LayerSpec::BinFc { d_in: 4096, d_out: 4096 };
        let dims = Dims { hw: 1, feat: 4096 };
        let portable = SimdBackend::with_engine(PopcountEngine::Portable).layer_secs(
            &eng,
            &layer,
            dims,
            8,
            ResidualMode::None,
            false,
        );
        let auto = SimdBackend::detect().layer_secs(
            &eng,
            &layer,
            dims,
            8,
            ResidualMode::None,
            false,
        );
        assert!(portable.is_finite() && portable > 0.0);
        // a wider engine can only be modeled faster-or-equal
        assert!(auto <= portable, "auto {auto} vs portable {portable}");
    }
}
