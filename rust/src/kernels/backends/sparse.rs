//! The sparse host backends (`Scheme::Spmm`, `Scheme::GcnFused`):
//! CSR-of-bit-lines operands (`bitops::SparseBitMatrix`) with work
//! proportional to *stored* 64-bit blocks instead of dense width.
//!
//! Two schemes share one implementation struct:
//!
//! * **SPMM** — the staged pipeline: FC layers run the sparse-operand
//!   Eq-2 delta dot (`sparse::sparse_pm1_dot`, present weight blocks
//!   only); GCN layers compute the full transposed combine image, then
//!   aggregate each output row over its adjacency row's stored blocks.
//! * **GCN-FUSED** — the fused GCN kernel: the combine is restricted
//!   up front to the node blocks any adjacency row actually touches
//!   (precomputed at prepare time — "memoized" once per layer, not per
//!   request), so untouched node blocks never run a combine at all,
//!   and aggregation reads the still-hot block lines.
//!
//! Both are bit-exact against the dense references at every sparsity.
//! Conv layers delegate to the fastpath's prepared form (sparsity
//! never pays on the im2row image), keeping the backends executable on
//! every model.
//!
//! ## Cost face
//!
//! The sparse schemes are host schemes (no GPU traces).  GCN layers
//! cost `combine_words + block_words * stored_blocks` at the detected
//! SIMD word rate — `secs = f(nnz_blocks, rows, words)`, the
//! sparsity-parameterized face the tuner fits a `secs_per_sparse_block`
//! coefficient for.  Dense layers run through the shared analytic host
//! curve with a *derated* word rate: the CSR indirection always loses
//! to the dense fastpath there, so the planner only selects a sparse
//! scheme where stored blocks actually shrink the work — which is
//! exactly the density crossover `tests/sparse_integration.rs` pins.

use anyhow::{ensure, Result};

use crate::bitops::{pack, pack64, BitMatrix, BitTensor4, SparseBitMatrix};
use crate::kernels::backend::{
    ExecCtx, KernelBackend, PreparedConv, PreparedFc, PreparedGcn,
};
use crate::kernels::backends::fastpath::{
    analytic_host_secs, host as fp_host, FastpathBackend, HostRates,
};
use crate::kernels::backends::simd::host as simd_host;
use crate::kernels::bconv::BconvProblem;
use crate::kernels::simd::PopcountEngine;
use crate::layout::LayoutKind;
use crate::nn::cost::{ResidualMode, Scheme};
use crate::nn::layer::{Dims, LayerSpec};
use crate::sim::{Engine, KernelTrace};
use crate::sparse::sparse_pm1_dot;
use crate::util::threadpool::scoped_chunks;

/// Cost-model constants of the sparse schemes.
pub mod host {
    /// Word-unit cost of touching one stored block in the staged SPMM
    /// aggregation: the AND+POPC itself plus the column-index load and
    /// the gather it steers.
    pub const SPMM_BLOCK_WORDS: f64 = 2.0;
    /// The fused kernel's per-block cost: same indirection, but the
    /// combine lines it reads are still cache-hot, so the constant is
    /// modeled slightly below the staged pipeline's.
    pub const FUSED_BLOCK_WORDS: f64 = 1.8;
    /// Dense-layer word-rate deration: on dense operands the CSR
    /// indirection is pure overhead, so the sparse schemes advertise
    /// half the fastpath's dense word throughput and never win a dense
    /// layer.
    pub const DENSE_DERATE: f64 = 0.5;
}

/// The sparse host backend behind both schemes.
pub struct SparseBackend {
    fused: bool,
    /// GCN word throughput: tracks the detected SIMD popcount engine —
    /// the inner loop is the same XOR/AND+POPC sweep, so the sparse
    /// and SIMD schemes are priced at a common rate and the planner's
    /// sparse-vs-dense choice depends only on block counts.
    word_rate: f64,
}

impl SparseBackend {
    /// The staged sparse backend (`Scheme::Spmm`).
    pub fn spmm() -> SparseBackend {
        SparseBackend {
            fused: false,
            word_rate: simd_host::word_ops_per_sec(PopcountEngine::detect()),
        }
    }

    /// The fused GCN backend (`Scheme::GcnFused`).
    pub fn gcn_fused() -> SparseBackend {
        SparseBackend {
            fused: true,
            word_rate: simd_host::word_ops_per_sec(PopcountEngine::detect()),
        }
    }

    fn block_words(&self) -> f64 {
        if self.fused {
            host::FUSED_BLOCK_WORDS
        } else {
            host::SPMM_BLOCK_WORDS
        }
    }
}

/// FC weights sparsified to CSR block lines once, off the request
/// path.  Absent blocks are all -1 (bit 0), so the delta dot is exact
/// at any density; on near-dense weights it degrades gracefully to a
/// dense sweep plus the index indirection.
struct SparseFc {
    w: SparseBitMatrix,
    d_in: usize,
    d_out: usize,
}

impl SparseFc {
    fn dot_rows(&self, rows64: &[u64], w64in: usize, batch: usize, ints: &mut [i32], threads: usize) {
        assert_eq!(ints.len(), batch * self.d_out, "dot staging size");
        scoped_chunks(ints, self.d_out, threads, |ni, out_row| {
            let x = &rows64[ni * w64in..(ni + 1) * w64in];
            // popc(x) hoisted once per input row (the delta identity)
            let px: u32 = x.iter().map(|v| v.count_ones()).sum();
            for (j, out) in out_row.iter_mut().enumerate() {
                let (bc, bb) = self.w.row_blocks(j);
                *out = sparse_pm1_dot(self.d_in, px, x, bc, bb);
            }
        });
    }
}

impl PreparedFc for SparseFc {
    fn scratch_words(&self, batch: usize) -> usize {
        batch * pack64::words64(self.d_in.div_ceil(32))
    }

    /// Native operand form: u64 lines, shared with the other host
    /// schemes so `Blocked64` edges chain across them with no repack.
    fn input_layout(&self) -> LayoutKind {
        LayoutKind::Blocked64
    }

    fn supports_input_layout(&self, layout: LayoutKind) -> bool {
        matches!(layout, LayoutKind::Row32 | LayoutKind::Blocked64)
    }

    fn bmm(&self, src: &[u32], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let wpl_in = self.d_in.div_ceil(32);
        let w64in = pack64::words64(wpl_in);
        assert!(src.len() >= batch * wpl_in, "input row buffer size");
        let rows = &mut ctx.words64[..batch * w64in];
        for (ni, row) in rows.chunks_exact_mut(w64in).enumerate() {
            pack64::repack64_into(&src[ni * wpl_in..(ni + 1) * wpl_in], row);
        }
        self.dot_rows(rows, w64in, batch, ints, ctx.threads);
    }

    fn bmm64(&self, src64: &[u64], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let w64in = pack64::words64(self.d_in.div_ceil(32));
        assert!(src64.len() >= batch * w64in, "u64 input row buffer size");
        self.dot_rows(&src64[..batch * w64in], w64in, batch, ints, ctx.threads);
    }
}

/// Shared prepared state of both sparse GCN kernels.
struct SparseGcn {
    adj: SparseBitMatrix,
    /// Out-degree per node (the aggregation's Eq-2 `n`).
    deg: Vec<i32>,
    /// Dense combine weights, row-major u32 lines.
    w: BitMatrix,
    /// Sorted unique node blocks any adjacency row touches — the fused
    /// kernel's combine domain.  With self-loops every block appears;
    /// without them, untouched node blocks never run a combine.
    touched: Vec<u32>,
    nodes: usize,
    d_in: usize,
    d_out: usize,
    fused: bool,
}

impl SparseGcn {
    fn new(adj: &SparseBitMatrix, w: &BitMatrix, fused: bool) -> Result<SparseGcn> {
        ensure!(adj.rows == adj.cols, "GCN adjacency must be square");
        ensure!(w.cols % 64 == 0, "BinGcn d_in must be a multiple of 64");
        ensure!(w.rows % 64 == 0, "BinGcn d_out must be a multiple of 64");
        let deg = (0..adj.rows).map(|r| adj.row_degree(r) as i32).collect();
        let mut touched: Vec<u32> = adj.block_cols.clone();
        touched.sort_unstable();
        touched.dedup();
        Ok(SparseGcn {
            adj: adj.clone(),
            deg,
            w: w.clone(),
            touched,
            nodes: adj.rows,
            d_in: w.cols,
            d_out: w.rows,
            fused,
        })
    }
}

impl PreparedGcn for SparseGcn {
    fn scratch_words(&self, _batch: usize) -> usize {
        // transposed combine image: d_out lines of `nodes` bits (items
        // run serially, so batch does not scale the scratch)
        self.d_out * self.nodes.div_ceil(64)
    }

    fn gcn(&self, src: &[u32], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let (nodes, d_in, d_out) = (self.nodes, self.d_in, self.d_out);
        let wpl_row = (nodes * d_in) / 32;
        let wpl_node = d_in / 32;
        let words_n = nodes.div_ceil(64);
        assert!(src.len() >= batch * wpl_row, "input row buffer size");
        assert_eq!(ints.len(), batch * nodes * d_out, "gcn staging size");
        let (ht, _) = ctx.words64.split_at_mut(d_out * words_n);
        for item in 0..batch {
            let line = &src[item * wpl_row..(item + 1) * wpl_row];
            // combine + binarize into transposed node-bit lines —
            // fused: only node blocks some adjacency row will read
            scoped_chunks(ht, words_n, ctx.threads, |f, hline| {
                hline.fill(0);
                let wline = self.w.line(f);
                if self.fused {
                    for &b in &self.touched {
                        let base = b as usize * 64;
                        for j in base..(base + 64).min(nodes) {
                            let a = &line[j * wpl_node..(j + 1) * wpl_node];
                            if pack::pm1_dot(a, wline, d_in) >= 0 {
                                hline[b as usize] |= 1u64 << (j - base);
                            }
                        }
                    }
                } else {
                    for j in 0..nodes {
                        let a = &line[j * wpl_node..(j + 1) * wpl_node];
                        if pack::pm1_dot(a, wline, d_in) >= 0 {
                            hline[j / 64] |= 1u64 << (j % 64);
                        }
                    }
                }
            });
            // aggregate over stored adjacency blocks only
            let dst = &mut ints[item * nodes * d_out..(item + 1) * nodes * d_out];
            let ht = &*ht;
            scoped_chunks(dst, d_out, ctx.threads, |i, row| {
                let (bc, bb) = self.adj.row_blocks(i);
                let deg = self.deg[i];
                for (f, out) in row.iter_mut().enumerate() {
                    let h = &ht[f * words_n..(f + 1) * words_n];
                    let mut pc = 0u32;
                    for (&b, &a) in bc.iter().zip(bb) {
                        pc += (a & h[b as usize]).count_ones();
                    }
                    *out = 2 * pc as i32 - deg;
                }
            });
        }
    }
}

impl KernelBackend for SparseBackend {
    fn scheme(&self) -> Scheme {
        if self.fused {
            Scheme::GcnFused
        } else {
            Scheme::Spmm
        }
    }

    /// Same FC layout faces as the other host schemes: `Blocked64`
    /// native, so host FC chains (fastpath/SIMD/sparse in any order)
    /// carry no repack edges.  GCN and conv activations stay `Row32`.
    fn preferred_input_layout(&self, layer: &LayerSpec) -> LayoutKind {
        match layer {
            LayerSpec::BinFc { .. } | LayerSpec::FinalFc { .. } => LayoutKind::Blocked64,
            _ => LayoutKind::Row32,
        }
    }

    fn output_layout(&self, layer: &LayerSpec) -> LayoutKind {
        match layer {
            LayerSpec::BinFc { .. } => LayoutKind::Blocked64,
            _ => LayoutKind::Row32,
        }
    }

    fn prepare_fc(&self, w: &BitMatrix) -> Result<Box<dyn PreparedFc>> {
        Ok(Box::new(SparseFc {
            w: SparseBitMatrix::from_bitmatrix(w),
            d_in: w.cols,
            d_out: w.rows,
        }))
    }

    /// Conv layers carry no sparsity story (the im2row image is dense
    /// by construction): delegate to the fastpath's prepared form, so
    /// the sparse schemes stay executable — and bit-exact — on every
    /// model.
    fn prepare_conv(
        &self,
        filter: &BitTensor4,
        p: BconvProblem,
    ) -> Result<Box<dyn PreparedConv>> {
        FastpathBackend.prepare_conv(filter, p)
    }

    fn prepare_gcn(
        &self,
        adj: &SparseBitMatrix,
        w: &BitMatrix,
    ) -> Result<Box<dyn PreparedGcn>> {
        Ok(Box::new(SparseGcn::new(adj, w, self.fused)?))
    }

    /// Host backend: no GPU trace face.
    fn layer_traces(
        &self,
        _layer: &LayerSpec,
        _dims: Dims,
        _batch: usize,
        _residual: ResidualMode,
        _model_has_residuals: bool,
    ) -> Vec<KernelTrace> {
        Vec::new()
    }

    fn layer_secs(
        &self,
        _engine: &Engine,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> f64 {
        match *layer {
            LayerSpec::BinGcn { nodes, d_in, d_out, nnz_blocks, .. } => {
                // the sparsity-parameterized face: combine words plus a
                // per-stored-block aggregation term
                let combine = (batch * nodes * d_out * d_in.div_ceil(64)) as f64;
                let agg = self.block_words() * (batch * d_out * nnz_blocks) as f64;
                let stream = (batch * nodes * (d_in + d_out)) as f64 / 8.0;
                (combine + agg) / self.word_rate
                    + stream / fp_host::BYTES_PER_SEC
                    + fp_host::DISPATCH_SECS
            }
            _ => {
                let rates = HostRates {
                    word_ops_per_sec: host::DENSE_DERATE * fp_host::WORD_OPS_PER_SEC,
                    fp_ops_per_sec: fp_host::FP_OPS_PER_SEC,
                    bytes_per_sec: fp_host::BYTES_PER_SEC,
                    dispatch_secs: fp_host::DISPATCH_SECS,
                };
                analytic_host_secs(&rates, layer, dims, batch, residual, model_has_residuals)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::Layout;
    use crate::sparse::{self, AdjKind, AdjSpec};
    use crate::util::Rng;

    fn naive_fc(a: &BitMatrix, w: &BitMatrix) -> Vec<i32> {
        let mut out = vec![0i32; a.rows * w.rows];
        for i in 0..a.rows {
            for j in 0..w.rows {
                out[i * w.rows + j] = pack::pm1_dot(a.line(i), w.line(j), w.cols);
            }
        }
        out
    }

    #[test]
    fn sparse_fc_matches_naive_at_every_density() {
        let mut rng = Rng::new(821);
        for density_pct in [0usize, 3, 25, 60, 100] {
            let (m, n, k) = (5, 9, 130);
            let a = BitMatrix::random(m, k, Layout::RowMajor, &mut rng);
            let mut w = BitMatrix::zeros(n, k, Layout::RowMajor);
            for r in 0..n {
                for c in 0..k {
                    if rng.gen_range(100) < density_pct {
                        w.set(r, c, true);
                    }
                }
            }
            let want = naive_fc(&a, &w);
            for backend in [SparseBackend::spmm(), SparseBackend::gcn_fused()] {
                let fc = backend.prepare_fc(&w).unwrap();
                let mut scratch = vec![0u64; fc.scratch_words(m)];
                let mut ints = vec![0i32; m * n];
                fc.bmm(
                    &a.data,
                    m,
                    &mut ints,
                    &mut ExecCtx { words64: &mut scratch, threads: 2 },
                );
                assert_eq!(ints, want, "{} density {density_pct}%", backend.name());
            }
        }
    }

    #[test]
    fn both_gcn_kernels_match_the_dense_reference() {
        let mut rng = Rng::new(822);
        let (nodes, d_in, d_out, batch) = (96usize, 64usize, 64usize, 2usize);
        for spec in [
            AdjSpec { kind: AdjKind::PowerLaw, degree: 4, seed: 5 },
            AdjSpec { kind: AdjKind::Grid, degree: 2, seed: 0 },
        ] {
            let adj = sparse::generate(spec, nodes);
            let w = BitMatrix::random(d_out, d_in, Layout::RowMajor, &mut rng);
            let x = BitMatrix::random(batch, nodes * d_in, Layout::RowMajor, &mut rng);
            let want = sparse::gcn_dense_reference(&adj, &w, &x);
            for backend in [SparseBackend::spmm(), SparseBackend::gcn_fused()] {
                let g = backend.prepare_gcn(&adj, &w).unwrap();
                let mut scratch = vec![0u64; g.scratch_words(batch)];
                let mut ints = vec![0i32; batch * nodes * d_out];
                g.gcn(
                    &x.data,
                    batch,
                    &mut ints,
                    &mut ExecCtx { words64: &mut scratch, threads: 3 },
                );
                assert_eq!(ints, want, "{} {spec:?}", backend.name());
            }
        }
    }

    #[test]
    fn cost_face_crosses_over_on_block_density() {
        use crate::kernels::backend::BackendRegistry;
        use crate::sim::RTX2080TI;
        let eng = Engine::new(&RTX2080TI);
        let reg = BackendRegistry::builtin();
        let secs = |scheme: Scheme, l: &LayerSpec, dims: Dims| {
            reg.get(scheme).unwrap().layer_secs(
                &eng,
                l,
                dims,
                8,
                ResidualMode::None,
                false,
            )
        };
        // low block density: sparse schemes beat both dense host schemes
        let pl_spec = AdjSpec { kind: AdjKind::PowerLaw, degree: 6, seed: 1 };
        let pl = sparse::generate(pl_spec, 512);
        let low = LayerSpec::BinGcn {
            nodes: 512,
            d_in: 64,
            d_out: 64,
            adj: pl_spec,
            nnz_blocks: pl.nnz_blocks(),
        };
        let dims_low = Dims { hw: 0, feat: 512 * 64 };
        for sparse_s in [Scheme::Spmm, Scheme::GcnFused] {
            for dense_s in [Scheme::Fastpath, Scheme::Simd] {
                assert!(
                    secs(sparse_s, &low, dims_low) < secs(dense_s, &low, dims_low),
                    "{} !< {} at low density",
                    sparse_s.name(),
                    dense_s.name()
                );
            }
        }
        // high block density: some dense host scheme beats both sparse
        let gr_spec = AdjSpec { kind: AdjKind::Grid, degree: 3, seed: 0 };
        let gr = sparse::generate(gr_spec, 128);
        let high = LayerSpec::BinGcn {
            nodes: 128,
            d_in: 64,
            d_out: 64,
            adj: gr_spec,
            nnz_blocks: gr.nnz_blocks(),
        };
        let dims_high = Dims { hw: 0, feat: 128 * 64 };
        let best_dense = secs(Scheme::Fastpath, &high, dims_high)
            .min(secs(Scheme::Simd, &high, dims_high));
        for sparse_s in [Scheme::Spmm, Scheme::GcnFused] {
            assert!(
                best_dense < secs(sparse_s, &high, dims_high),
                "dense !< {} at high density",
                sparse_s.name()
            );
        }
        // dense layers: the derate keeps sparse schemes strictly behind
        // the fastpath everywhere
        let fc = LayerSpec::BinFc { d_in: 4096, d_out: 4096 };
        let dims_fc = Dims { hw: 0, feat: 4096 };
        assert!(secs(Scheme::Fastpath, &fc, dims_fc) < secs(Scheme::Spmm, &fc, dims_fc));
        assert!(
            secs(Scheme::Fastpath, &fc, dims_fc) < secs(Scheme::GcnFused, &fc, dims_fc)
        );
        // and the fused constant undercuts the staged one on GCN layers
        assert!(secs(Scheme::GcnFused, &low, dims_low) < secs(Scheme::Spmm, &low, dims_low));
    }
}
