//! The two bit-tensor-core backends: BTC (Design-1, sequential bit
//! format) and BTC-FMT (the FSB format of §5.1, Design-2 conv /
//! Design-3 BMM traces).  Host execution is the shared scalar path.

use anyhow::Result;

use crate::bitops::{BitMatrix, BitTensor4};
use crate::kernels::backend::{KernelBackend, PreparedConv, PreparedFc};
use crate::kernels::bconv::{self, BconvProblem, BconvScheme};
use crate::kernels::bmm::{self, BmmProblem, BmmScheme};
use crate::kernels::IoMode;
use crate::nn::cost::{ResidualMode, Scheme};
use crate::nn::layer::{Dims, LayerSpec};
use crate::sim::KernelTrace;

use super::scalar::{ScalarConv, ScalarFc};
use super::{assemble_gpu_traces, round_up};

/// One BTC scheme row: the default sequential bit format, or the FSB
/// format (§5.1) that makes the WMMA leading dimension stride-friendly.
pub struct BtcBackend {
    fmt: bool,
}

impl BtcBackend {
    pub fn new(fmt: bool) -> BtcBackend {
        BtcBackend { fmt }
    }

    fn conv_traces(
        &self,
        dims: Dims,
        batch: usize,
        o: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Vec<KernelTrace> {
        let p = BconvProblem {
            hw: dims.hw,
            n: round_up(batch, 8),
            c: round_up(dims.feat, 128),
            o: round_up(o, 8),
            k,
            stride,
            pad,
        };
        if self.fmt {
            bconv::btc::BconvDesign2.traces(p, IoMode::BnnSpecific)
        } else {
            bconv::btc::BconvDesign1.traces(p, IoMode::BnnSpecific)
        }
    }

    fn fc_traces(&self, batch: usize, d_in: usize, d_out: usize) -> Vec<KernelTrace> {
        let p = BmmProblem {
            m: round_up(batch, 8),
            n: round_up(d_out, 128),
            k: round_up(d_in, 128),
        };
        if self.fmt {
            bmm::btc::Design3.traces(p, IoMode::BnnSpecific)
        } else {
            bmm::btc::Design1.traces(p, IoMode::BnnSpecific)
        }
    }
}

impl KernelBackend for BtcBackend {
    fn scheme(&self) -> Scheme {
        if self.fmt {
            Scheme::BtcFmt
        } else {
            Scheme::Btc
        }
    }

    fn prepare_fc(&self, w: &BitMatrix) -> Result<Box<dyn PreparedFc>> {
        Ok(Box::new(ScalarFc::new(w)))
    }

    fn prepare_conv(
        &self,
        filter: &BitTensor4,
        _p: BconvProblem,
    ) -> Result<Box<dyn PreparedConv>> {
        Ok(Box::new(ScalarConv::new(filter)))
    }

    fn layer_traces(
        &self,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> Vec<KernelTrace> {
        assemble_gpu_traces(
            layer,
            dims,
            batch,
            residual,
            model_has_residuals,
            |o, k, stride, pad| self.conv_traces(dims, batch, o, k, stride, pad),
            |d_in, d_out| self.fc_traces(batch, d_in, d_out),
        )
    }
}
