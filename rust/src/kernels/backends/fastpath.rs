//! The blocked-u64 host backend (`Scheme::Fastpath`): prepared weights
//! are u64-repacked lines (`bitops::pack64`) and `FastConvFilter`
//! images; execution runs the cache-blocked XNOR-popcount kernels of
//! `kernels::fastpath`; the cost face is an analytic host model (no
//! GPU traces — the backend runs on the serving host's cores).

use anyhow::{ensure, Result};

use crate::bitops::pack64::{self, BitMatrix64};
use crate::bitops::{BitMatrix, BitTensor4};
use crate::kernels::backend::{ExecCtx, KernelBackend, PreparedConv, PreparedFc};
use crate::kernels::bconv::BconvProblem;
use crate::kernels::fastpath::{self, FastConvFilter};
use crate::layout::LayoutKind;
use crate::nn::cost::{ResidualMode, Scheme};
use crate::nn::layer::{Dims, LayerSpec};
use crate::sim::{Engine, KernelTrace};

/// Calibrated host constants for the fastpath cost model — the blocked
/// u64 backend runs on the serving host's cores, not the GPU, so its
/// cost is modeled analytically instead of through `sim::KernelTrace`.
/// Constants are deliberately conservative multi-core laptop/server
/// numbers; refresh them against `cargo bench --bench bench_kernels`
/// when the host class changes.
pub mod host {
    /// u64 XOR+POPC+accumulate word ops per second (all cores, blocked).
    pub const WORD_OPS_PER_SEC: f64 = 6.0e9;
    /// f32 multiply-accumulates per second (the first BWN layer).
    pub const FP_OPS_PER_SEC: f64 = 8.0e9;
    /// streamed bytes per second (packing, pooling, residual traffic).
    pub const BYTES_PER_SEC: f64 = 1.2e10;
    /// scoped fork/join + repack latency per parallel section.
    pub const DISPATCH_SECS: f64 = 3.0e-6;
}

/// The blocked-u64 host backend.
pub struct FastpathBackend;

/// FC weights repacked to u64 lines once, off the request path.
struct FastpathFc {
    w64: BitMatrix64,
}

impl PreparedFc for FastpathFc {
    fn scratch_words(&self, batch: usize) -> usize {
        batch * self.w64.words_per_line
    }

    /// Native operand form: u64 lines.  Fed `Blocked64` directly (a
    /// planned layout edge), `bmm64` skips the per-call u32 -> u64
    /// repack below entirely.
    fn input_layout(&self) -> LayoutKind {
        LayoutKind::Blocked64
    }

    fn supports_input_layout(&self, layout: LayoutKind) -> bool {
        matches!(layout, LayoutKind::Row32 | LayoutKind::Blocked64)
    }

    fn bmm(&self, src: &[u32], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let d_in = self.w64.cols;
        let d_out = self.w64.rows;
        let wpl_in = d_in.div_ceil(32);
        let w64in = self.w64.words_per_line;
        debug_assert_eq!(pack64::words64(wpl_in), w64in, "weight repack width");
        assert!(src.len() >= batch * wpl_in, "input row buffer size");
        assert_eq!(ints.len(), batch * d_out, "dot staging size");
        // repack the u32 input rows into the u64 scratch, then run the
        // blocked BMM against the prepared u64 weight lines
        let rows = &mut ctx.words64[..batch * w64in];
        for (ni, row) in rows.chunks_exact_mut(w64in).enumerate() {
            pack64::repack64_into(&src[ni * wpl_in..(ni + 1) * wpl_in], row);
        }
        fastpath::bmm::dot_lines(
            rows,
            &self.w64.data,
            w64in,
            batch,
            d_out,
            d_in,
            ints,
            ctx.threads,
        );
    }

    /// The native-layout path: the caller (an executor materializing a
    /// planned `Blocked64` edge) already holds the u64 input image, so
    /// the blocked BMM runs with no conversion and no scratch.
    fn bmm64(&self, src64: &[u64], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let d_in = self.w64.cols;
        let d_out = self.w64.rows;
        let w64in = self.w64.words_per_line;
        assert!(src64.len() >= batch * w64in, "u64 input row buffer size");
        assert_eq!(ints.len(), batch * d_out, "dot staging size");
        fastpath::bmm::dot_lines(
            &src64[..batch * w64in],
            &self.w64.data,
            w64in,
            batch,
            d_out,
            d_in,
            ints,
            ctx.threads,
        );
    }
}

/// Conv filter repacked to fastpath u64 lines (+ per-tap popcounts for
/// the excluded-padding correction) once, off the request path.
struct FastpathConv {
    f: FastConvFilter,
}

impl PreparedConv for FastpathConv {
    fn scratch_words(&self, p: BconvProblem) -> usize {
        // the bit-im2row image: one u64 line per output sample
        fastpath::bconv::rows(p) * self.f.row_words
    }

    fn bconv(&self, src: &[u32], p: BconvProblem, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let need = fastpath::bconv::rows(p) * self.f.row_words;
        fastpath::bconv::bconv_into(
            src,
            p,
            &self.f,
            &mut ctx.words64[..need],
            ints,
            ctx.threads,
        );
    }
}

/// Rate constants of an analytic host cost model.
///
/// The fastpath and SIMD backends share **one model shape** — the
/// curve `secs = fp/F + words/W + stream/B + DISPATCH` that
/// `tuner::features::layer_features` mirrors — and differ only in
/// these coefficients, so the tuner fits either backend with the same
/// regressors.
pub(crate) struct HostRates {
    /// u64 XOR+POPC+accumulate word ops per second (all cores).
    pub word_ops_per_sec: f64,
    /// f32 multiply-accumulates per second (the first BWN layer).
    pub fp_ops_per_sec: f64,
    /// streamed bytes per second (packing, pooling, residual traffic).
    pub bytes_per_sec: f64,
    /// scoped fork/join + repack latency per parallel section.
    pub dispatch_secs: f64,
}

/// Host-model seconds for one layer under `rates` (shared by every
/// analytic host backend).
pub(crate) fn analytic_host_secs(
    rates: &HostRates,
    layer: &LayerSpec,
    dims: Dims,
    batch: usize,
    residual: ResidualMode,
    model_has_residuals: bool,
) -> f64 {
    let out_hw = |k: usize, stride: usize, pad: usize| -> usize {
        (dims.hw + 2 * pad - k) / stride + 1
    };
    match *layer {
        LayerSpec::FirstConv { c, o, k, stride, pad } => {
            let ohw = out_hw(k, stride, pad);
            let fp = (ohw * ohw * batch * o * k * k * c) as f64;
            fp / rates.fp_ops_per_sec + rates.dispatch_secs
        }
        LayerSpec::BinConv { o, k, stride, pad, residual: is_res, .. } => {
            // filters beyond the host tap limit cannot run here: cost
            // them infinite so no plan ever selects the scheme
            if k * k > fastpath::bconv::MAX_TAPS {
                return f64::INFINITY;
            }
            let c = dims.feat;
            let ohw = out_hw(k, stride, pad);
            let words = (ohw * ohw * batch * o * k * k * c.div_ceil(64)) as f64;
            // im2row build + output repack are streamed bytes
            let stream = (ohw * ohw * batch * (k * k * c.div_ceil(8) + o)) as f64;
            let mut secs = words / rates.word_ops_per_sec
                + stream / rates.bytes_per_sec
                + rates.dispatch_secs;
            if is_res && model_has_residuals && residual != ResidualMode::None {
                let out_dims = dims.after(layer);
                // fp16 residual save/fetch, same accounting as the GPU path
                let xfers = match residual {
                    ResidualMode::Full => 2,
                    ResidualMode::SaveOnly | ResidualMode::FetchOnly => 1,
                    ResidualMode::None => 0,
                };
                secs += (out_dims.flat() * batch * 2 * xfers) as f64
                    / rates.bytes_per_sec;
            }
            secs
        }
        LayerSpec::BinFc { d_in, d_out } | LayerSpec::FinalFc { d_in, d_out } => {
            let words = (batch * d_out * d_in.div_ceil(64)) as f64;
            words / rates.word_ops_per_sec + rates.dispatch_secs
        }
        LayerSpec::BinGcn { nodes, d_in, d_out, .. } => {
            // dense host execution: per-node combine plus a dense
            // AND+POPC aggregation sweep over every column block of
            // every adjacency row (the DenseGcn default kernel)
            let words = (batch * nodes * d_out * (d_in.div_ceil(64) + nodes.div_ceil(64)))
                as f64;
            let stream = (batch * nodes * (d_in + d_out)) as f64 / 8.0;
            words / rates.word_ops_per_sec
                + stream / rates.bytes_per_sec
                + rates.dispatch_secs
        }
        LayerSpec::Pool => {
            // 4 packed loads + 1 store per output word
            let bytes = (dims.flat() * batch).div_ceil(8) as f64;
            bytes * 5.0 / rates.bytes_per_sec + rates.dispatch_secs
        }
    }
}

/// Host-model seconds for one layer under the fastpath.
fn fastpath_layer_secs(
    layer: &LayerSpec,
    dims: Dims,
    batch: usize,
    residual: ResidualMode,
    model_has_residuals: bool,
) -> f64 {
    let rates = HostRates {
        word_ops_per_sec: host::WORD_OPS_PER_SEC,
        fp_ops_per_sec: host::FP_OPS_PER_SEC,
        bytes_per_sec: host::BYTES_PER_SEC,
        dispatch_secs: host::DISPATCH_SECS,
    };
    analytic_host_secs(&rates, layer, dims, batch, residual, model_has_residuals)
}

impl KernelBackend for FastpathBackend {
    fn scheme(&self) -> Scheme {
        Scheme::Fastpath
    }

    /// FC layers natively consume `Blocked64` (the u64 operand form
    /// the blocked BMM runs on); conv layers consume `Row32` HWNC
    /// words and stage their own `Im2rowStaged` image internally.
    fn preferred_input_layout(&self, layer: &LayerSpec) -> LayoutKind {
        match layer {
            LayerSpec::BinFc { .. } | LayerSpec::FinalFc { .. } => LayoutKind::Blocked64,
            _ => LayoutKind::Row32,
        }
    }

    /// Chain FC activations in `Blocked64`: when the next layer is
    /// also fastpath the executor packs thresholded bits straight into
    /// u64 words and no conversion happens on the edge at all.
    /// (`FinalFc` emits real-valued logits — no packed output layout.)
    fn output_layout(&self, layer: &LayerSpec) -> LayoutKind {
        match layer {
            LayerSpec::BinFc { .. } => LayoutKind::Blocked64,
            _ => LayoutKind::Row32,
        }
    }

    fn prepare_fc(&self, w: &BitMatrix) -> Result<Box<dyn PreparedFc>> {
        Ok(Box::new(FastpathFc { w64: BitMatrix64::from_bitmatrix(w) }))
    }

    fn prepare_conv(
        &self,
        filter: &BitTensor4,
        p: BconvProblem,
    ) -> Result<Box<dyn PreparedConv>> {
        // reject here, at build time, instead of panicking on the
        // first request inside the serving worker
        ensure!(
            p.k * p.k <= fastpath::bconv::MAX_TAPS,
            "{}x{} filter exceeds the fastpath tap limit ({} taps)",
            p.k,
            p.k,
            fastpath::bconv::MAX_TAPS
        );
        Ok(Box::new(FastpathConv { f: FastConvFilter::prepare(filter) }))
    }

    /// The fastpath has no GPU trace face.
    fn layer_traces(
        &self,
        _layer: &LayerSpec,
        _dims: Dims,
        _batch: usize,
        _residual: ResidualMode,
        _model_has_residuals: bool,
    ) -> Vec<KernelTrace> {
        Vec::new()
    }

    fn layer_secs(
        &self,
        _engine: &Engine,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> f64 {
        fastpath_layer_secs(layer, dims, batch, residual, model_has_residuals)
    }
}
