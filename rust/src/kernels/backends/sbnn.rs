//! The four software-BNN backends (SBNN-32, SBNN-32-Fine, SBNN-64,
//! SBNN-64-Fine): BSTC-style word kernels on the GPU cost model,
//! scalar u32 execution on the host.

use anyhow::Result;

use crate::bitops::{BitMatrix, BitTensor4};
use crate::kernels::backend::{KernelBackend, PreparedConv, PreparedFc};
use crate::kernels::bconv::{self, BconvProblem, BconvScheme};
use crate::kernels::bmm::{self, BmmProblem, BmmScheme};
use crate::kernels::IoMode;
use crate::nn::cost::{ResidualMode, Scheme};
use crate::nn::layer::{Dims, LayerSpec};
use crate::sim::KernelTrace;

use super::scalar::{ScalarConv, ScalarFc};
use super::{assemble_gpu_traces, round_up};

/// One SBNN scheme row: word size 32 or 64, optionally the
/// fine-grained (4-way split) occupancy variant.
pub struct SbnnBackend {
    word: usize,
    fine: bool,
}

impl SbnnBackend {
    pub fn new(word: usize, fine: bool) -> SbnnBackend {
        assert!(word == 32 || word == 64, "SBNN word size is 32 or 64");
        SbnnBackend { word, fine }
    }

    fn conv_traces(
        &self,
        dims: Dims,
        batch: usize,
        o: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Vec<KernelTrace> {
        let p = BconvProblem {
            hw: dims.hw,
            n: batch,
            c: round_up(dims.feat, self.word),
            o: round_up(o, 32),
            k,
            stride,
            pad,
        };
        let mut traces =
            bconv::bstc::BstcBconv::new(self.word).traces(p, IoMode::BnnSpecific);
        if self.fine {
            traces.iter_mut().for_each(make_fine);
        }
        traces
    }

    fn fc_traces(&self, batch: usize, d_in: usize, d_out: usize) -> Vec<KernelTrace> {
        let p = BmmProblem {
            m: round_up(batch, self.word),
            n: round_up(d_out, self.word),
            k: round_up(d_in, self.word),
        };
        bmm::bstc::BstcBmm::new(self.word, self.fine).traces(p, IoMode::BnnSpecific)
    }
}

/// Fine-grained SBNN: split each warp's work 4 ways for occupancy (the
/// "-Fine" rows): more, lighter warps plus atomic combine overhead.
fn make_fine(t: &mut KernelTrace) {
    t.grid_ctas *= 4;
    t.warp.intu_ops = t.warp.intu_ops / 4 + 32;
    t.warp.sfu_ops /= 4;
    t.warp.bulk_load_bytes /= 4;
    t.warp.bulk_store_bytes += 64; // partial-sum atomics
}

impl KernelBackend for SbnnBackend {
    fn scheme(&self) -> Scheme {
        match (self.word, self.fine) {
            (32, false) => Scheme::Sbnn32,
            (32, true) => Scheme::Sbnn32Fine,
            (64, false) => Scheme::Sbnn64,
            _ => Scheme::Sbnn64Fine,
        }
    }

    fn prepare_fc(&self, w: &BitMatrix) -> Result<Box<dyn PreparedFc>> {
        Ok(Box::new(ScalarFc::new(w)))
    }

    fn prepare_conv(
        &self,
        filter: &BitTensor4,
        _p: BconvProblem,
    ) -> Result<Box<dyn PreparedConv>> {
        Ok(Box::new(ScalarConv::new(filter)))
    }

    fn layer_traces(
        &self,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> Vec<KernelTrace> {
        assemble_gpu_traces(
            layer,
            dims,
            batch,
            residual,
            model_has_residuals,
            |o, k, stride, pad| self.conv_traces(dims, batch, o, k, stride, pad),
            |d_in, d_out| self.fc_traces(batch, d_in, d_out),
        )
    }
}
