//! Shared host execution for the six GPU schemes: plain u32 word
//! kernels, bit-exact Eq-2 with the paper's exclude-amended padding.
//!
//! On the serving CPU the functional semantics of every GPU scheme
//! are identical exact integer arithmetic (asserted by the
//! kernels-equivalence tests), so the SBNN and BTC backends all share
//! these prepared-layer implementations; what differs per scheme is
//! the cost face.  On a Turing GPU the scheme choice would select the
//! actual kernel.

use crate::bitops::{pack, BitMatrix, BitTensor4, Layout, TensorLayout};
use crate::kernels::backend::{ExecCtx, PreparedConv, PreparedFc};
use crate::kernels::bconv::BconvProblem;
use crate::util::threadpool::scoped_chunks;

/// Scalar FC: a plain clone of the packed weight rows; Eq-2 dots via
/// `pack::pm1_dot` per (row, weight-row) pair, row-parallel.
pub struct ScalarFc {
    w: BitMatrix,
}

impl ScalarFc {
    pub fn new(w: &BitMatrix) -> ScalarFc {
        assert_eq!(w.layout, Layout::RowMajor, "FC weights are row-major packed");
        ScalarFc { w: w.clone() }
    }
}

impl PreparedFc for ScalarFc {
    fn bmm(&self, src: &[u32], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let d_in = self.w.cols;
        let d_out = self.w.rows;
        let wpl_in = d_in.div_ceil(32);
        assert!(src.len() >= batch * wpl_in, "input row buffer size");
        assert_eq!(ints.len(), batch * d_out, "dot staging size");
        scoped_chunks(ints, d_out, ctx.threads, |ni, row| {
            let a = &src[ni * wpl_in..(ni + 1) * wpl_in];
            for (j, out) in row.iter_mut().enumerate() {
                *out = pack::pm1_dot(a, self.w.line(j), d_in);
            }
        });
    }
}

/// Scalar conv: a plain clone of the KKOC packed filter; direct
/// XOR-popcount cross-correlation over the HWNC input words with the
/// exclude-amended Eq-2 correction, parallel over output rows.
pub struct ScalarConv {
    filter: BitTensor4,
}

impl ScalarConv {
    pub fn new(filter: &BitTensor4) -> ScalarConv {
        assert_eq!(filter.layout, TensorLayout::Kkoc, "conv filters are KKOC packed");
        ScalarConv { filter: filter.clone() }
    }
}

impl PreparedConv for ScalarConv {
    fn bconv(&self, src: &[u32], p: BconvProblem, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let [kh, kw, o, c] = self.filter.dims;
        assert_eq!(kh, p.k, "filter extent");
        assert_eq!(kw, p.k, "filter extent");
        assert_eq!(o, p.o, "output channels");
        assert_eq!(c, p.c, "input channels");
        let wi = p.c.div_ceil(32);
        let ohw = p.out_hw();
        assert!(src.len() >= p.hw * p.hw * p.n * wi, "input buffer size");
        assert_eq!(ints.len(), ohw * ohw * p.n * p.o, "output buffer size");
        let chunk = ohw * p.n * p.o;
        scoped_chunks(ints, chunk, ctx.threads, |op, row| {
            for oq in 0..ohw {
                let seg = &mut row[oq * p.n * p.o..(oq + 1) * p.n * p.o];
                seg.fill(0);
                let mut exclude = 0usize;
                for r in 0..p.k {
                    for s in 0..p.k {
                        let i = (op * p.stride + r) as isize - p.pad as isize;
                        let j = (oq * p.stride + s) as isize - p.pad as isize;
                        if i < 0 || i >= p.hw as isize || j < 0 || j >= p.hw as isize {
                            exclude += 1;
                            continue;
                        }
                        let (i, j) = (i as usize, j as usize);
                        for ni in 0..p.n {
                            let abase = ((i * p.hw + j) * p.n + ni) * wi;
                            let a = &src[abase..abase + wi];
                            let out_row = &mut seg[ni * p.o..(ni + 1) * p.o];
                            for (oi, out) in out_row.iter_mut().enumerate() {
                                let b = self.filter.inner(r, s, oi);
                                let mut pc = 0u32;
                                for (x, y) in a.iter().zip(b.iter()) {
                                    pc += (x ^ y).count_ones();
                                }
                                *out += pc as i32;
                            }
                        }
                    }
                }
                // Eq 2 with the padding amendment: n_valid - 2*popc
                let n_valid = (p.c * (p.k * p.k - exclude)) as i32;
                for v in seg.iter_mut() {
                    *v = n_valid - 2 * *v;
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{bconv, bmm};
    use crate::util::Rng;

    #[test]
    fn scalar_fc_matches_naive_bmm() {
        let mut rng = Rng::new(41);
        for (m, n, k) in [(8, 16, 96), (5, 7, 130), (1, 9, 33)] {
            let a = BitMatrix::random(m, k, Layout::RowMajor, &mut rng);
            let w = BitMatrix::random(n, k, Layout::RowMajor, &mut rng);
            // naive_ref wants B column-major; weight rows ARE packed
            // columns of B, so rebuild the same bits column-major
            let mut b = BitMatrix::zeros(k, n, Layout::ColMajor);
            for j in 0..n {
                for i in 0..k {
                    if w.get(j, i) {
                        b.set(i, j, true);
                    }
                }
            }
            let want = bmm::naive_ref(&a, &b);
            let fc = ScalarFc::new(&w);
            let mut ints = vec![0i32; m * n];
            let mut ctx = ExecCtx { words64: &mut [], threads: 2 };
            fc.bmm(&a.data, m, &mut ints, &mut ctx);
            assert_eq!(ints, want, "{m}x{n}x{k}");
        }
    }

    #[test]
    fn scalar_conv_matches_naive_ref() {
        let mut rng = Rng::new(42);
        for p in [
            BconvProblem { hw: 6, n: 4, c: 40, o: 5, k: 3, stride: 1, pad: 1 },
            BconvProblem { hw: 5, n: 2, c: 128, o: 8, k: 3, stride: 2, pad: 0 },
        ] {
            let input =
                BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, &mut rng);
            let filter =
                BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, &mut rng);
            let want = bconv::naive_ref(&input, &filter, p);
            let conv = ScalarConv::new(&filter);
            let mut ints = vec![0i32; p.out_elems()];
            let mut ctx = ExecCtx { words64: &mut [], threads: 2 };
            conv.bconv(&input.data, p, &mut ints, &mut ctx);
            assert_eq!(ints, want, "{p:?}");
        }
    }
}
