//! Builtin [`KernelBackend`](crate::kernels::backend::KernelBackend)
//! implementations — one per scheme row of Tables 6–7 plus the blocked
//! u64 host fastpath — and the trace plumbing they share.
//!
//! * [`sbnn`] — the four software-BNN rows (SBNN-32/-Fine, SBNN-64/
//!   -Fine): BSTC-style word kernels, cost-modeled through
//!   `kernels::{bmm,bconv}::bstc` traces.
//! * [`btc`] — the two bit-tensor-core rows (BTC, BTC-FMT): Design-1
//!   vs the FSB-format Design-2/3 traces.
//! * [`scalar`] — the shared *host execution* face of all six GPU
//!   schemes.  On the serving CPU their functional semantics are
//!   identical exact integer Eq-2 math (that is what the
//!   kernels-equivalence tests guarantee); the scheme choice drives
//!   cost accounting, and on a Turing GPU would select the kernel.
//! * [`fastpath`] — the blocked-u64 XNOR-popcount host backend
//!   (`kernels::fastpath`): u64-repacked prepared weights, bit-im2row
//!   conv lowering, and an analytic host cost model instead of GPU
//!   traces.
//! * [`simd`] — the explicit-SIMD host backend (`kernels::simd`):
//!   the fastpath's blocking and lowering with the inner popcount
//!   dispatched through a runtime-detected `PopcountEngine`
//!   (AVX2 popcnt / AVX-512 vpopcntdq / NEON cnt / portable).
//! * [`sparse`] — the two sparse host backends (SPMM, GCN-FUSED):
//!   CSR-of-bit-lines operands with work proportional to stored
//!   64-bit blocks, and the binary-GCN aggregate+combine kernels.
//!
//! The free functions here assemble per-layer traces from a backend's
//! conv/FC cores: the scheme-independent pieces (first-layer BWN
//! trace, residual save/fetch traffic, OR-pool, the FinalFc int-store
//! + batch-norm adjustment, the fused-kernel zero-launch rule) live in
//! one place so every backend prices them identically — exactly as the
//! pre-registry `nn::cost` did.

pub mod btc;
pub mod fastpath;
pub mod scalar;
pub mod sbnn;
pub mod simd;
pub mod sparse;

use crate::kernels::backend::KernelBackend;
use crate::nn::cost::ResidualMode;
use crate::nn::layer::{Dims, LayerSpec};
use crate::sim::KernelTrace;

/// The builtin backends, in `Scheme::all()` order.
pub fn builtin() -> Vec<Box<dyn KernelBackend>> {
    vec![
        Box::new(sbnn::SbnnBackend::new(32, false)),
        Box::new(sbnn::SbnnBackend::new(32, true)),
        Box::new(sbnn::SbnnBackend::new(64, false)),
        Box::new(sbnn::SbnnBackend::new(64, true)),
        Box::new(btc::BtcBackend::new(false)),
        Box::new(btc::BtcBackend::new(true)),
        Box::new(fastpath::FastpathBackend),
        Box::new(simd::SimdBackend::detect()),
        Box::new(sparse::SparseBackend::spmm()),
        Box::new(sparse::SparseBackend::gcn_fused()),
    ]
}

pub(crate) fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

/// First-layer BWN trace (same for every GPU scheme — BTC can't run it).
fn first_conv_trace(
    dims: Dims,
    batch: usize,
    o: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> KernelTrace {
    let c = dims.feat;
    let ohw = (dims.hw + 2 * pad - k) / stride + 1;
    let outputs = ohw * ohw * o * batch;
    let mut t = KernelTrace::new("first_conv");
    let warps = outputs.div_ceil(32).max(1);
    t.warps_per_cta = 8;
    t.grid_ctas = warps.div_ceil(8).max(1);
    // per warp: 32 outputs; per output K*K*C adds with bit extraction
    // from the shared-memory weight buffer (§6.1: extract each weight
    // bit, add or subtract the fp input element)
    let taps = k * k * c;
    t.warp.fp_ops = 32 * taps * 3; // extract + select + add/sub per tap
    // fp32 input window loads, partially cached across channel warps
    t.warp.bulk_load_bytes = (taps * 4 * 32 / 8).max(128);
    t.warp.bulk_store_bytes = 32 / 8; // thresholded bits out
    t.warp.cta_syncs = 1;
    let in_bytes = (dims.hw * dims.hw * c * batch * 4) as f64;
    t.compulsory_bytes = in_bytes + (outputs / 8) as f64;
    t.load_footprint_bytes = in_bytes;
    // the window walk is pixel-tiled: resident set stays small
    t.wave_bytes_per_cta = 64.0 * 1024.0;
    t
}

/// Residual save/fetch traffic for one block boundary (real-valued
/// residuals, §6.1: "these residuals are real-valued").
fn residual_trace(elems: usize, mode: ResidualMode) -> Option<KernelTrace> {
    let (save, fetch) = match mode {
        ResidualMode::Full => (true, true),
        ResidualMode::SaveOnly => (true, false),
        ResidualMode::FetchOnly => (false, true),
        ResidualMode::None => return None,
    };
    let mut t = KernelTrace::new("residual");
    let warps = (elems / 1024).max(1);
    t.warps_per_cta = 8;
    t.grid_ctas = warps.div_ceil(8).max(1);
    let per_warp = 1024 * 2; // residuals kept in fp16 (half the traffic)
    if save {
        t.warp.bulk_store_bytes += per_warp;
    }
    if fetch {
        t.warp.bulk_load_bytes += per_warp;
        t.warp.fp_ops += 1024; // add into the activation
    }
    t.compulsory_bytes = (elems * 2 * ((save as usize) + (fetch as usize))) as f64;
    Some(t)
}

/// The OR-pool trace (scheme-independent packed-byte streaming).
fn pool_trace(dims: Dims, batch: usize) -> KernelTrace {
    let mut t = KernelTrace::new("pool");
    let elems = dims.flat() * batch / 8; // packed bytes
    t.grid_ctas = (elems / 4096).max(1);
    t.warps_per_cta = 8;
    t.warp.bulk_load_bytes = 4096;
    t.warp.bulk_store_bytes = 1024;
    t.warp.intu_ops = 3 * 1024;
    t
}

/// Assemble one layer's traces for a GPU scheme from its conv/FC trace
/// cores, in the fused-kernel view (no per-layer launches): the
/// scheme-independent first-conv/pool/residual/classifier-head pieces
/// are shared here so every backend prices them identically.
pub(crate) fn assemble_gpu_traces(
    layer: &LayerSpec,
    dims: Dims,
    batch: usize,
    residual: ResidualMode,
    model_has_residuals: bool,
    conv_core: impl Fn(usize, usize, usize, usize) -> Vec<KernelTrace>,
    fc_core: impl Fn(usize, usize) -> Vec<KernelTrace>,
) -> Vec<KernelTrace> {
    let mut traces: Vec<KernelTrace> = match *layer {
        LayerSpec::FirstConv { o, k, stride, pad, .. } => {
            vec![first_conv_trace(dims, batch, o, k, stride, pad)]
        }
        LayerSpec::BinConv { o, k, stride, pad, residual: is_res, pool: _, .. } => {
            let mut v = conv_core(o, k, stride, pad);
            if is_res && model_has_residuals {
                let out_dims = dims.after(layer);
                let elems = out_dims.flat() * batch;
                if let Some(rt) = residual_trace(elems, residual) {
                    v.push(rt);
                }
            }
            v
        }
        LayerSpec::BinFc { d_in, d_out } => fc_core(d_in, d_out),
        LayerSpec::BinGcn { nodes, d_in, d_out, .. } => {
            // The GPU schemes ship no sparse aggregation kernel: price
            // the layer as the dense (nodes*d_in) x (nodes*d_out)
            // matmul the masked aggregation would have to fall back to
            // — finite (the planner can always produce a plan) but far
            // above the host sparse schemes, so GCN layers plan onto
            // the host.
            fc_core(nodes * d_in, nodes * d_out)
        }
        LayerSpec::FinalFc { d_in, d_out } => {
            // real-valued output: int store + bn, no output binarize
            let mut v = fc_core(d_in, round_up(d_out, 8));
            for t in &mut v {
                t.warp.bulk_store_bytes += 8 * 4; // int32 out per tile
                t.warp.fp_ops += 64; // bn scale/shift
            }
            v
        }
        LayerSpec::Pool => vec![pool_trace(dims, batch)],
    };
    // the fused kernel has no per-layer launches
    for t in &mut traces {
        t.launches = 0;
    }
    traces
}
