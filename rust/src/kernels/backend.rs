//! The `KernelBackend` trait: one kernel-provider abstraction behind
//! every scheme the engine can plan or execute.
//!
//! The paper's core finding is that per-scheme *data-format co-design*
//! (the FSB packing of §5.1, the u64 line repacking of the host
//! fastpath) is what unlocks throughput — which means every scheme
//! carries scheme-specific packed weights, scratch shapes, and a cost
//! model.  Before this module those concerns were wired through four
//! independent dispatch sites (the forward-path layer match, ad-hoc
//! `BmmScheme`/`BconvScheme` boxing in `nn::cost`, fastpath
//! special-cases in `engine::executor`, and the `EngineModel`
//! constructors).  `KernelBackend` folds them into one trait with
//! three faces:
//!
//! * **prepare** — `prepare_fc` / `prepare_conv` turn raw packed
//!   weights into opaque prepared-layer handles ([`PreparedFc`],
//!   [`PreparedConv`]) that own whatever scheme-specific weight image
//!   the backend wants (u64 lines, per-tap popcounts, plain clones)
//!   and report their u64 scratch needs so the arena can be sized
//!   up front;
//! * **execute** — `PreparedFc::bmm` / `PreparedConv::bconv` run the
//!   bit-exact Eq-2 kernels over caller-owned buffers and an
//!   [`ExecCtx`] (arena scratch slice + scoped-worker count), keeping
//!   the request path allocation-free;
//! * **cost** — `layer_secs` / `layer_traces` expose the scheme's
//!   simulated timing (GPU `KernelTrace`s for the Tables-6/7 rows, an
//!   analytic host model for the fastpath), which is what
//!   `engine::Planner` and `nn::cost` rank.
//!
//! [`BackendRegistry`] keyed by [`Scheme`] is the single dispatch
//! point.  `nn::forward`, `nn::cost`, `engine::planner`, and
//! `engine::executor` all consult a registry instead of matching on
//! `Scheme`, so a new backend (an AVX-512 `vpopcntdq` path, a
//! NUMA-sharded host, a test double) drops in by implementing the
//! trait and registering — no dispatch-site edits.  See
//! `docs/ENGINE.md` ("Adding a backend") and
//! `rust/tests/backend_equivalence.rs` for a registry-extension proof.

use std::sync::OnceLock;

use anyhow::{ensure, Result};

use crate::bitops::pack;
use crate::bitops::pack64::BitMatrix64;
use crate::bitops::{BitMatrix, BitTensor4, SparseBitMatrix};
use crate::kernels::bconv::BconvProblem;
use crate::layout::LayoutKind;
use crate::nn::cost::{ResidualMode, Scheme};
use crate::nn::layer::{Dims, LayerSpec};
use crate::sim::{Engine, KernelTrace};
use crate::util::threadpool::scoped_chunks;

/// Per-call execution context handed to prepared layers: a slice of
/// the caller's pre-sized u64 scratch arena and the scoped-worker
/// count for this parallel section (>= 1; callers apply their own
/// small-work serial cutoff before building the context).
pub struct ExecCtx<'a> {
    /// u64 operand scratch — at least the prepared layer's
    /// `scratch_words` for the executing shape.
    pub words64: &'a mut [u64],
    /// scoped worker threads for this section (1 = serial).
    pub threads: usize,
}

/// Opaque prepared weights for one binarized FC layer.  Owns whatever
/// packed weight image its backend needs; built once off the request
/// path by [`KernelBackend::prepare_fc`].
pub trait PreparedFc: Send + Sync {
    /// u64 scratch words needed to execute a batch of `batch` rows
    /// (monotone in `batch`, so sizing at batch capacity covers every
    /// smaller request).
    fn scratch_words(&self, batch: usize) -> usize {
        let _ = batch;
        0
    }

    /// The activation layout this handle consumes *natively* — with no
    /// internal conversion.  The planner prices feeding any other
    /// layout as an (implicit or explicit) repack; the executor feeds
    /// whatever the plan's layout edge says, validated against
    /// [`PreparedFc::supports_input_layout`] at build time.
    fn input_layout(&self) -> LayoutKind {
        LayoutKind::Row32
    }

    /// The input layouts this handle can execute from.  `Row32` is the
    /// universal default every backend must accept; a handle that
    /// also executes its native form directly (see
    /// [`PreparedFc::bmm64`]) additionally reports it here.
    fn supports_input_layout(&self, layout: LayoutKind) -> bool {
        layout == LayoutKind::Row32
    }

    /// Eq-2 dots of every (input row, weight row) pair:
    /// `ints[bi * d_out + j] = dot(src row bi, weight row j)`.
    ///
    /// `src` holds `batch` row-packed lines of `d_in` bits
    /// (`ceil(d_in/32)` u32 words per line); `ints.len()` must be
    /// exactly `batch * d_out`.  Exact integer arithmetic: every
    /// backend produces bit-identical values.
    fn bmm(&self, src: &[u32], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>);

    /// [`PreparedFc::bmm`] from a pre-repacked `Blocked64` input:
    /// `src64` holds `batch` lines of `ceil(d_in/64)` u64 words each
    /// (the `bitops::pack64` pairing of the `Row32` rows).  Only
    /// called when `supports_input_layout(Blocked64)` — the executor
    /// validates that at build time, so the default is unreachable for
    /// `Row32`-only backends.
    fn bmm64(&self, src64: &[u64], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let _ = (src64, batch, ints, ctx);
        unreachable!(
            "backend does not execute Blocked64 input; \
             override supports_input_layout + bmm64 together"
        );
    }
}

/// Opaque prepared weights for one binarized conv layer.
pub trait PreparedConv: Send + Sync {
    /// u64 scratch words needed to execute problem `p` (monotone in
    /// `p.n`, the batch).
    fn scratch_words(&self, p: BconvProblem) -> usize {
        let _ = p;
        0
    }

    /// The HWNC activation layout this handle consumes.  Conv inputs
    /// are `Row32` for every current backend (the fastpath's staged
    /// im2row image is built *inside* the kernel from `Row32` words —
    /// its `Im2rowStaged` staging layout is reported through the
    /// backend's cost face, not consumed across a layer edge).
    fn input_layout(&self) -> LayoutKind {
        LayoutKind::Row32
    }

    /// The input layouts this handle can execute from (`Row32` only
    /// for every current conv implementation).
    fn supports_input_layout(&self, layout: LayoutKind) -> bool {
        layout == LayoutKind::Row32
    }

    /// Exclude-amended Eq-2 cross-correlation (the paper's bit-padding
    /// amendment): `ints[((op*ohw + oq)*n + ni)*o + oi]`, the
    /// `kernels::bconv::naive_ref` layout.  `src` is the HWNC packed
    /// input (`((i*hw + j)*n + ni) * ceil(c/32)` u32 word layout —
    /// exactly `BitTensor4`'s HWNC storage, shared with the arena);
    /// `ints.len()` must be exactly `out_hw^2 * n * o`.
    fn bconv(&self, src: &[u32], p: BconvProblem, ints: &mut [i32], ctx: &mut ExecCtx<'_>);
}

/// Opaque prepared state for one binary GCN layer: the graph adjacency
/// staged in whatever form the backend aggregates from, plus the
/// combine weights.  Built once per model (the arena executor stages
/// adjacency exactly once, off the request path) by
/// [`KernelBackend::prepare_gcn`].
pub trait PreparedGcn: Send + Sync {
    /// u64 scratch words needed to execute a batch of `batch` rows
    /// (monotone in `batch`).
    fn scratch_words(&self, batch: usize) -> usize {
        let _ = batch;
        0
    }

    /// One binary GCN layer over a batch (combine, binarize,
    /// aggregate — the exact integer semantics of
    /// `sparse::gcn_dense_reference`): `src` holds `batch` row-packed
    /// lines of `nodes * d_in` bits; `ints[(bi*nodes + i)*d_out + f]`
    /// receives the aggregated integer for node `i`, feature `f`.
    /// Every backend produces bit-identical values.
    fn gcn(&self, src: &[u32], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>);
}

/// The default [`PreparedGcn`]: adjacency densified to u64 lines,
/// aggregation swept over *every* block.  Exact for any backend; the
/// sparse backends override `prepare_gcn` with block-sparse staging.
struct DenseGcn {
    adj64: BitMatrix64,
    deg: Vec<i32>,
    w: BitMatrix,
    nodes: usize,
    d_in: usize,
    d_out: usize,
}

impl DenseGcn {
    fn new(adj: &SparseBitMatrix, w: &BitMatrix) -> Result<DenseGcn> {
        ensure!(adj.rows == adj.cols, "GCN adjacency must be square");
        ensure!(w.cols % 64 == 0, "BinGcn d_in must be a multiple of 64");
        ensure!(w.rows % 64 == 0, "BinGcn d_out must be a multiple of 64");
        let deg = (0..adj.rows).map(|r| adj.row_degree(r) as i32).collect();
        Ok(DenseGcn {
            adj64: adj.to_bitmatrix64(),
            deg,
            w: w.clone(),
            nodes: adj.rows,
            d_in: w.cols,
            d_out: w.rows,
        })
    }
}

impl PreparedGcn for DenseGcn {
    fn scratch_words(&self, _batch: usize) -> usize {
        // the transposed binarized combine: d_out lines of `nodes` bits
        // (items run serially, so batch does not scale the scratch)
        self.d_out * self.nodes.div_ceil(64)
    }

    fn gcn(&self, src: &[u32], batch: usize, ints: &mut [i32], ctx: &mut ExecCtx<'_>) {
        let (nodes, d_in, d_out) = (self.nodes, self.d_in, self.d_out);
        let wpl_row = (nodes * d_in) / 32;
        let wpl_node = d_in / 32;
        let words_n = nodes.div_ceil(64);
        assert!(src.len() >= batch * wpl_row, "input row buffer size");
        assert_eq!(ints.len(), batch * nodes * d_out, "gcn staging size");
        let (ht, _) = ctx.words64.split_at_mut(d_out * words_n);
        for item in 0..batch {
            let line = &src[item * wpl_row..(item + 1) * wpl_row];
            // combine + binarize, transposed: line f = node bits of
            // feature f (parallel over feature lines)
            scoped_chunks(ht, words_n, ctx.threads, |f, hline| {
                hline.fill(0);
                for j in 0..nodes {
                    let a = &line[j * wpl_node..(j + 1) * wpl_node];
                    if pack::pm1_dot(a, self.w.line(f), d_in) >= 0 {
                        hline[j / 64] |= 1u64 << (j % 64);
                    }
                }
            });
            // aggregate: dense AND+POPC sweep over every column block
            let dst = &mut ints[item * nodes * d_out..(item + 1) * nodes * d_out];
            let ht = &*ht;
            scoped_chunks(dst, d_out, ctx.threads, |i, row| {
                let arow = self.adj64.line(i);
                let deg = self.deg[i];
                for (f, out) in row.iter_mut().enumerate() {
                    let h = &ht[f * words_n..(f + 1) * words_n];
                    let pc: u32 = arow
                        .iter()
                        .zip(h)
                        .map(|(a, b)| (a & b).count_ones())
                        .sum();
                    *out = 2 * pc as i32 - deg;
                }
            });
        }
    }
}

/// A kernel provider for one scheme: weight preparation, bit-exact
/// execution, and the cost/trace face the planner ranks.
pub trait KernelBackend: Send + Sync {
    /// The scheme this backend serves — its key in a [`BackendRegistry`].
    fn scheme(&self) -> Scheme;

    /// Registry/reporting name (defaults to the scheme name).
    fn name(&self) -> &'static str {
        self.scheme().name()
    }

    /// The activation layout this backend natively consumes for
    /// `layer` — the planning-time face of the prepared handles'
    /// `input_layout` (queried before any weights exist).  The planner
    /// prices feeding any other layout as a repack, and prefers edges
    /// that hand the backend its native form.  Default: `Row32`, the
    /// universal format every backend accepts.
    ///
    /// CONTRACT: declaring a non-`Row32` preference commits this
    /// backend's prepared handles to executing it — the planner emits
    /// layout edges from this answer alone, and the executor then
    /// validates `PreparedFc::supports_input_layout` at build time and
    /// errors on a mismatch.  Override the two together (as the
    /// fastpath does), or override neither.
    fn preferred_input_layout(&self, layer: &LayerSpec) -> LayoutKind {
        let _ = layer;
        LayoutKind::Row32
    }

    /// The activation layout this backend's layers chain *from* most
    /// cheaply — i.e. the layout the executor should pack `layer`'s
    /// thresholded output into when the next layer runs on this
    /// backend too.  Default `Row32`; the fastpath returns `Blocked64`
    /// for FC layers so consecutive fastpath FC layers skip the u32
    /// round-trip entirely.
    fn output_layout(&self, layer: &LayerSpec) -> LayoutKind {
        let _ = layer;
        LayoutKind::Row32
    }

    /// Prepare a binarized FC weight matrix (`d_out x d_in` row-major
    /// packed) into this backend's execution form.
    fn prepare_fc(&self, w: &BitMatrix) -> Result<Box<dyn PreparedFc>>;

    /// Prepare a KKOC packed conv filter for problems shaped like `p`
    /// (`p.n` is the batch *capacity*; execution may use any smaller
    /// batch).  Backends reject unsupported shapes here, at build
    /// time, instead of panicking on the first request.
    fn prepare_conv(&self, filter: &BitTensor4, p: BconvProblem) -> Result<Box<dyn PreparedConv>>;

    /// Prepare one binary GCN layer: stage the adjacency mask (square,
    /// `nodes x nodes`, self-loops expected) and the dense combine
    /// weights (`d_out x d_in` row-major packed, dims multiples of 64)
    /// into this backend's aggregation form.  The default stages a
    /// dense u64 adjacency image and sweeps every block — exact for
    /// any backend; the sparse backends override it with block-sparse
    /// aggregation proportional to stored blocks.
    fn prepare_gcn(
        &self,
        adj: &SparseBitMatrix,
        w: &BitMatrix,
    ) -> Result<Box<dyn PreparedGcn>> {
        Ok(Box::new(DenseGcn::new(adj, w)?))
    }

    /// The scheme's kernel traces for one layer in the fused-kernel
    /// view (no per-layer launches).  `dims` is the layer's *input*
    /// dims.  Host backends with no GPU face return an empty vec and
    /// override [`KernelBackend::layer_secs`] instead.
    fn layer_traces(
        &self,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> Vec<KernelTrace>;

    /// Simulated seconds of one layer (compute only — per-layer sync
    /// and the one-off launch overhead are accounted at the model
    /// level).  Default: sum the trace costs on `engine`.
    fn layer_secs(
        &self,
        engine: &Engine,
        layer: &LayerSpec,
        dims: Dims,
        batch: usize,
        residual: ResidualMode,
        model_has_residuals: bool,
    ) -> f64 {
        self.layer_traces(layer, dims, batch, residual, model_has_residuals)
            .iter()
            .map(|t| engine.cost(t).total_secs)
            .sum()
    }
}

/// The single dispatch point: an ordered set of backends keyed by
/// [`Scheme`].  Order is registration order and drives planner
/// tie-breaking (first-registered wins a cost tie), so the builtin
/// registry registers in `Scheme::all()` order.
pub struct BackendRegistry {
    entries: Vec<Box<dyn KernelBackend>>,
}

impl BackendRegistry {
    /// An empty registry (test harnesses that want full control).
    pub fn empty() -> BackendRegistry {
        BackendRegistry { entries: Vec::new() }
    }

    /// All builtin backends, in `Scheme::all()` order: the six
    /// Tables-6/7 GPU schemes plus the blocked-u64 host fastpath.
    pub fn builtin() -> BackendRegistry {
        let mut r = BackendRegistry::empty();
        for b in crate::kernels::backends::builtin() {
            r.register(b);
        }
        r
    }

    /// The shared process-wide builtin registry — what the
    /// registry-less convenience entry points (`nn::forward::forward`,
    /// `nn::cost::layer_secs`, `EngineExecutor::new`) dispatch
    /// through.  Custom registries are passed explicitly.
    pub fn global() -> &'static BackendRegistry {
        static GLOBAL: OnceLock<BackendRegistry> = OnceLock::new();
        GLOBAL.get_or_init(BackendRegistry::builtin)
    }

    /// Register a backend under its `scheme()` key: replaces an
    /// existing entry for that scheme in place (keeping its order),
    /// appends otherwise.
    pub fn register(&mut self, backend: Box<dyn KernelBackend>) {
        let key = backend.scheme();
        match self.entries.iter_mut().find(|b| b.scheme() == key) {
            Some(slot) => *slot = backend,
            None => self.entries.push(backend),
        }
    }

    /// The backend registered for `scheme`, if any.
    pub fn get(&self, scheme: Scheme) -> Option<&dyn KernelBackend> {
        self.entries
            .iter()
            .find(|b| b.scheme() == scheme)
            .map(|b| b.as_ref())
    }

    /// All registered backends, in registration order.
    pub fn backends(&self) -> impl Iterator<Item = &dyn KernelBackend> {
        self.entries.iter().map(|b| b.as_ref())
    }

    /// Registered schemes, in registration order.
    pub fn schemes(&self) -> Vec<Scheme> {
        self.entries.iter().map(|b| b.scheme()).collect()
    }

    /// Registered scheme names, in registration order — the list
    /// `bench_kernels --list-schemes` prints and the plan cache embeds
    /// for staleness detection.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|b| b.scheme().name()).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl std::fmt::Debug for BackendRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("BackendRegistry").field(&self.names()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_covers_every_scheme_in_order() {
        let r = BackendRegistry::builtin();
        let want: Vec<&'static str> = Scheme::all().iter().map(|s| s.name()).collect();
        assert_eq!(r.names(), want);
        assert_eq!(r.schemes(), Scheme::all().to_vec());
        for s in Scheme::all() {
            let b = r.get(s).expect("builtin backend");
            assert_eq!(b.scheme(), s);
            assert_eq!(b.name(), s.name());
        }
        assert_eq!(r.len(), Scheme::all().len());
    }

    #[test]
    fn global_registry_is_builtin() {
        assert_eq!(
            BackendRegistry::global().names(),
            BackendRegistry::builtin().names()
        );
    }

    #[test]
    fn layout_face_defaults_to_row32_except_host_fc() {
        let fc = LayerSpec::BinFc { d_in: 512, d_out: 512 };
        let conv = LayerSpec::BinConv {
            c: 64,
            o: 64,
            k: 3,
            stride: 1,
            pad: 1,
            pool: false,
            residual: false,
        };
        for b in BackendRegistry::builtin().backends() {
            let want_fc = if b.scheme().is_host() {
                LayoutKind::Blocked64
            } else {
                LayoutKind::Row32
            };
            assert_eq!(b.preferred_input_layout(&fc), want_fc, "{}", b.name());
            assert_eq!(b.output_layout(&fc), want_fc, "{}", b.name());
            // conv activations stay Row32 everywhere
            assert_eq!(b.preferred_input_layout(&conv), LayoutKind::Row32);
        }
    }

    #[test]
    fn default_prepare_gcn_matches_dense_reference() {
        use crate::sparse::{self, AdjKind, AdjSpec};
        use crate::util::Rng;
        let mut rng = Rng::new(721);
        let (nodes, d_in, d_out, batch) = (24usize, 64usize, 64usize, 3usize);
        let adj =
            sparse::generate(AdjSpec { kind: AdjKind::Grid, degree: 2, seed: 0 }, nodes);
        let w =
            BitMatrix::random(d_out, d_in, crate::bitops::Layout::RowMajor, &mut rng);
        let x = BitMatrix::random(
            batch,
            nodes * d_in,
            crate::bitops::Layout::RowMajor,
            &mut rng,
        );
        let want = sparse::gcn_dense_reference(&adj, &w, &x);
        // a GPU-scheme backend never overrides prepare_gcn: this
        // exercises the DenseGcn default
        let reg = BackendRegistry::builtin();
        let g = reg.get(Scheme::Btc).unwrap().prepare_gcn(&adj, &w).unwrap();
        let mut scratch = vec![0u64; g.scratch_words(batch)];
        let mut ints = vec![0i32; batch * nodes * d_out];
        g.gcn(
            &x.data,
            batch,
            &mut ints,
            &mut ExecCtx { words64: &mut scratch, threads: 2 },
        );
        assert_eq!(ints, want);
    }

    #[test]
    fn register_replaces_in_place() {
        struct Stub(Scheme);
        impl KernelBackend for Stub {
            fn scheme(&self) -> Scheme {
                self.0
            }
            fn prepare_fc(&self, _: &BitMatrix) -> Result<Box<dyn PreparedFc>> {
                anyhow::bail!("stub")
            }
            fn prepare_conv(
                &self,
                _: &BitTensor4,
                _: BconvProblem,
            ) -> Result<Box<dyn PreparedConv>> {
                anyhow::bail!("stub")
            }
            fn layer_traces(
                &self,
                _: &LayerSpec,
                _: Dims,
                _: usize,
                _: ResidualMode,
                _: bool,
            ) -> Vec<KernelTrace> {
                Vec::new()
            }
        }
        let mut r = BackendRegistry::builtin();
        let order_before = r.names();
        r.register(Box::new(Stub(Scheme::Sbnn64)));
        // same keys, same order; the entry itself was swapped
        assert_eq!(r.names(), order_before);
        assert!(r
            .get(Scheme::Sbnn64)
            .unwrap()
            .prepare_fc(&BitMatrix::zeros(1, 1, crate::bitops::Layout::RowMajor))
            .is_err());
    }
}
