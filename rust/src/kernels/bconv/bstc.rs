//! BSTC software BConv (bconv32 / bconv64 in Figs 20–23): the SC'19
//! design — each thread walks a filter window sequentially with a status
//! variable for out-of-frame entries, xor/popc on INTUs + SFUs.

use crate::bitops::BitTensor4;
use crate::sim::KernelTrace;

use super::super::IoMode;
use super::{naive_ref, with_general_io, BconvProblem, BconvScheme};

/// BSTC BConv with 32- or 64-bit word granularity.
pub struct BstcBconv {
    pub word: usize,
}

impl BstcBconv {
    pub fn new(word: usize) -> BstcBconv {
        assert!(word == 32 || word == 64);
        BstcBconv { word }
    }
}

impl BconvScheme for BstcBconv {
    fn name(&self) -> &'static str {
        if self.word == 32 {
            "bconv32"
        } else {
            "bconv64"
        }
    }

    fn uses_tensorcores(&self) -> bool {
        false
    }

    fn compute(&self, input: &BitTensor4, filter: &BitTensor4, p: BconvProblem) -> Vec<i32> {
        // word-sequential walk; u64 pairs words exactly like the real
        // 64-bit kernel (numerically identical to the naive reference)
        naive_ref(input, filter, p)
    }

    fn traces(&self, p: BconvProblem, mode: IoMode) -> Vec<KernelTrace> {
        let mut t = KernelTrace::new(self.name());
        let ohw = p.out_hw();
        // one warp covers 32 output channels for one (pixel, image)
        let warps = ohw * ohw * p.n * p.o.div_ceil(32);
        t.warps_per_cta = 8;
        t.grid_ctas = warps.div_ceil(8).max(1);
        let valid_taps = (p.k * p.k) as f64 * 0.92; // border exclusion avg
        let words32 = (p.c as f64 / 32.0 * valid_taps).ceil() as usize;
        match self.word {
            32 => {
                // per lane: words32 x (xor + popc + add)
                t.warp.intu_ops = 2 * 32 * words32;
                t.warp.sfu_ops = 32 * words32;
            }
            _ => {
                let w64 = words32 / 2;
                t.warp.intu_ops = 2 * 32 * w64 + 32 * w64;
                t.warp.sfu_ops = 32 * w64;
            }
        }
        // input window + filter loads (filter reused via shared memory)
        t.warp.bulk_load_bytes = words32 * 4 * 32 / 8 + p.k * p.k * p.c / 8;
        t.warp.intu_ops += p.k * p.k * 2; // frame-status bookkeeping
        match mode {
            IoMode::General => t.warp.bulk_store_bytes = 32 * 4,
            IoMode::BnnSpecific => {
                t.warp.intu_ops += 40;
                t.warp.bulk_store_bytes = 4;
            }
        }
        let out_bytes = match mode {
            IoMode::General => (p.out_elems() * 4) as f64,
            IoMode::BnnSpecific => (p.out_elems() / 8) as f64,
        };
        t.compulsory_bytes = p.input_bytes() + p.filter_bytes() + out_bytes;
        t.load_footprint_bytes = p.input_bytes() + p.filter_bytes();
        t.wave_bytes_per_cta =
            ((p.k * p.k + 2) * p.c * p.n.min(16) / 8) as f64 + p.filter_bytes() / 8.0;
        match mode {
            IoMode::General => with_general_io(vec![t], p),
            IoMode::BnnSpecific => vec![t],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Engine, RTX2080TI};

    #[test]
    fn bconv64_beats_bconv32() {
        // the 64-bit path halves the instruction stream
        let e = Engine::new(&RTX2080TI);
        let p = BconvProblem::paper_sweep(1024, 1024);
        let t32 = super::super::simulate(&e, &BstcBconv::new(32), p, IoMode::General);
        let t64 = super::super::simulate(&e, &BstcBconv::new(64), p, IoMode::General);
        assert!(t64 < t32, "t64 {t64} !< t32 {t32}");
    }

    #[test]
    fn names() {
        assert_eq!(BstcBconv::new(32).name(), "bconv32");
        assert_eq!(BstcBconv::new(64).name(), "bconv64");
    }
}
