//! cuDNN FP16 baselines of Figs 20–23: `cudnn-base` (no workspace —
//! direct/implicit-GEMM with poor staging) and `cudnn-fast` (plenty of
//! workspace — the best algorithm cuDNN finds, Winograd-like for 3x3/s1).

use crate::bitops::BitTensor4;
use crate::sim::KernelTrace;

use super::super::IoMode;
use super::{naive_ref, BconvProblem, BconvScheme};

fn cudnn_trace(
    name: &str,
    p: BconvProblem,
    efficiency: f64,
    flop_scale: f64,
    traffic_mult: f64,
) -> Vec<KernelTrace> {
    let mut t = KernelTrace::new(name);
    let ohw = p.out_hw();
    // implicit-GEMM tiling: 128x128 output tiles over (OHW*N, O)
    let gemm_m = ohw * ohw * p.n;
    t.warps_per_cta = 8;
    t.grid_ctas = (gemm_m.div_ceil(128) * p.o.div_ceil(128)).max(1);
    t.smem_per_cta = 32 * 1024;
    let fmas = p.ops() / 2.0 * flop_scale;
    let total_warps = (t.grid_ctas * t.warps_per_cta) as f64;
    t.warp.hmma_fmas = (fmas / total_warps / efficiency) as usize;
    // fp16 traffic: input re-read per output-channel tile + filter + out
    let in_fp16 = (p.hw * p.hw * p.n * p.c * 2) as f64;
    let fil_fp16 = (p.k * p.k * p.c * p.o * 2) as f64;
    let out_fp16 = (p.out_elems() * 2) as f64;
    let traffic = in_fp16 * traffic_mult + fil_fp16 + out_fp16;
    t.warp.bulk_load_bytes = (traffic / total_warps) as usize;
    t.warp.cta_syncs = 2 * (p.k * p.k * p.c / 32);
    t.compulsory_bytes = in_fp16 + fil_fp16 + out_fp16;
    t.load_footprint_bytes = in_fp16 + fil_fp16;
    t.wave_bytes_per_cta = 64.0 * 1024.0;
    vec![t]
}

/// cuDNN with no workspace: direct algorithm, input re-streamed per
/// filter tap.
pub struct CudnnBase;

impl BconvScheme for CudnnBase {
    fn name(&self) -> &'static str {
        "cudnn_base"
    }

    fn uses_tensorcores(&self) -> bool {
        true
    }

    fn supports(&self, p: BconvProblem, mode: IoMode) -> bool {
        mode == IoMode::General && p.c % 8 == 0 && p.o % 8 == 0
    }

    fn compute(&self, input: &BitTensor4, filter: &BitTensor4, p: BconvProblem) -> Vec<i32> {
        naive_ref(input, filter, p)
    }

    fn traces(&self, p: BconvProblem, mode: IoMode) -> Vec<KernelTrace> {
        let _ = mode;
        cudnn_trace("cudnn_base", p, 0.40, 1.0, p.k as f64 * p.k as f64 * 0.5)
    }
}

/// cuDNN with ample workspace: Winograd-class algorithm for 3x3/s1
/// (2.25x fewer multiplies), well-staged traffic.
pub struct CudnnFast;

impl BconvScheme for CudnnFast {
    fn name(&self) -> &'static str {
        "cudnn_fast"
    }

    fn uses_tensorcores(&self) -> bool {
        true
    }

    fn supports(&self, p: BconvProblem, mode: IoMode) -> bool {
        mode == IoMode::General && p.c % 8 == 0 && p.o % 8 == 0
    }

    fn compute(&self, input: &BitTensor4, filter: &BitTensor4, p: BconvProblem) -> Vec<i32> {
        naive_ref(input, filter, p)
    }

    fn traces(&self, p: BconvProblem, mode: IoMode) -> Vec<KernelTrace> {
        let _ = mode;
        let flop_scale = if p.k == 3 && p.stride == 1 { 1.0 / 2.25 } else { 1.0 };
        cudnn_trace("cudnn_fast", p, 0.75, flop_scale, 2.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Engine, RTX2080TI};

    #[test]
    fn fast_beats_base() {
        let e = Engine::new(&RTX2080TI);
        for c in [128usize, 512, 2048] {
            let p = BconvProblem::paper_sweep(c, c);
            let base = super::super::simulate(&e, &CudnnBase, p, IoMode::General);
            let fast = super::super::simulate(&e, &CudnnFast, p, IoMode::General);
            assert!(fast < base, "c={c}: fast {fast} !< base {base}");
        }
    }

    #[test]
    fn btc_beats_cudnn_by_an_order() {
        // Figs 20–23: up to 25x over cuDNN-base around C=O=640
        let e = Engine::new(&RTX2080TI);
        let p = BconvProblem::paper_sweep(640, 640);
        let base = super::super::simulate(&e, &CudnnBase, p, IoMode::General);
        let fmt = super::super::simulate(
            &e,
            &super::super::btc::BconvDesign2,
            p,
            IoMode::General,
        );
        assert!(base / fmt > 6.0, "speedup only {}", base / fmt);
    }
}
