//! BTC BConv designs (Listing 6 + the FSB variant, §5.3).

use crate::bitops::{BitTensor4, TensorLayout};
use crate::sim::{KernelTrace, MemSpace};

use super::super::IoMode;
use super::{with_general_io, BconvProblem, BconvScheme};

/// Shared warp-tile compute: 8-batch x 8-outch output tiles per pixel,
/// 128-channel BMMA steps, exclude-amended padding — exactly Listing 6.
fn btc_compute(input: &BitTensor4, filter: &BitTensor4, p: BconvProblem) -> Vec<i32> {
    assert_eq!(input.layout, TensorLayout::Hwnc);
    assert_eq!(filter.layout, TensorLayout::Kkoc);
    let [h, w, n, c] = input.dims;
    let [kh, kw, o, _] = filter.dims;
    let ohw = p.out_hw();
    let cw = c / 32;
    let mut out = vec![0i32; ohw * ohw * n * o];
    for op in 0..ohw {
        for oq in 0..ohw {
            for nt in (0..n).step_by(8) {
                for ot in (0..o).step_by(8) {
                    // one warp: c_frag accumulates popc; exclude tracked
                    let mut acc = [[0i32; 8]; 8];
                    let mut exclude = 0i32;
                    for r in 0..kh {
                        for s in 0..kw {
                            let i = (op * p.stride + r) as isize - p.pad as isize;
                            let j = (oq * p.stride + s) as isize - p.pad as isize;
                            if i < 0 || i >= h as isize || j < 0 || j >= w as isize {
                                exclude += 1;
                                continue;
                            }
                            let (i, j) = (i as usize, j as usize);
                            // 128-bit channel steps (bmma_sync per step)
                            for ks in (0..cw).step_by(4) {
                                let ke = (ks + 4).min(cw);
                                for (bi, arow) in (nt..nt + 8).enumerate() {
                                    let a = &input.inner(i, j, arow)[ks..ke];
                                    for (bj, ocol) in (ot..ot + 8).enumerate() {
                                        let b = &filter.inner(r, s, ocol)[ks..ke];
                                        let mut pc = 0u32;
                                        for (x, y) in a.iter().zip(b.iter()) {
                                            pc += (x ^ y).count_ones();
                                        }
                                        acc[bi][bj] += pc as i32;
                                    }
                                }
                            }
                        }
                    }
                    // Listing 6 line 36: amendment for padding + Eq 2
                    let n_valid = (c as i32) * ((kh * kw) as i32 - exclude);
                    for bi in 0..8 {
                        for bj in 0..8 {
                            out[((op * ohw + oq) * n + nt + bi) * o + ot + bj] =
                                n_valid - 2 * acc[bi][bj];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Core trace shared by the two BTC designs; `ldm` is what differs:
/// Design-1 loads with the HWNC channel stride (`ldm = C`), the FSB
/// design with the fixed 128-bit tile stride.
fn btc_trace(name: &str, p: BconvProblem, mode: IoMode, ldm: usize) -> Vec<KernelTrace> {
    let mut t = KernelTrace::new(name);
    let ohw = p.out_hw();
    let warps = ohw * ohw * (p.n / 8) * (p.o / 8);
    t.warps_per_cta = 4;
    t.grid_ctas = warps.div_ceil(4).max(1);
    // interior point: KK taps x C/128 bmma steps; borders excluded —
    // average valid-tap fraction folded in
    let interior = ((ohw * ohw) as f64 - (4 * ohw) as f64 * (p.pad as f64) / 2.0)
        .max(1.0)
        / (ohw * ohw) as f64;
    let steps = ((p.k * p.k * (p.c / 128)) as f64 * interior).ceil() as usize;
    t.warp.load_tiles(ldm, MemSpace::Global, 2 * steps);
    t.warp.bmma_same_acc_ops = steps;
    t.warp.intu_ops = p.k * p.k * 4; // frame checks + exclude bookkeeping
    match mode {
        IoMode::General => t.warp.store_tiles(MemSpace::Global, 1),
        IoMode::BnnSpecific => {
            t.warp.intu_ops += 80;
            t.warp.bulk_store_bytes += 8;
        }
    }
    let out_bytes = match mode {
        IoMode::General => (p.out_elems() * 4) as f64,
        IoMode::BnnSpecific => (p.out_elems() / 8) as f64,
    };
    t.compulsory_bytes = p.input_bytes() + p.filter_bytes() + out_bytes;
    t.load_footprint_bytes = p.input_bytes() + p.filter_bytes();
    // pixel-local reuse: a wave works on neighbouring output pixels, so
    // the resident set is the filter + a halo of input rows, not the
    // whole activation tensor
    t.wave_bytes_per_cta =
        ((p.k * p.k + 2) * p.c * p.n.min(16) / 8) as f64 + p.filter_bytes() / 8.0;
    match mode {
        IoMode::General => with_general_io(vec![t], p),
        IoMode::BnnSpecific => vec![t],
    }
}

/// BTC BConv Design-1 (`bmma` in Figs 20–23): HWNC input loaded with
/// `ldm = in_channels`.
pub struct BconvDesign1;

impl BconvScheme for BconvDesign1 {
    fn name(&self) -> &'static str {
        "bconv_bmma"
    }

    fn uses_tensorcores(&self) -> bool {
        true
    }

    fn compute(&self, input: &BitTensor4, filter: &BitTensor4, p: BconvProblem) -> Vec<i32> {
        btc_compute(input, filter, p)
    }

    fn traces(&self, p: BconvProblem, mode: IoMode) -> Vec<KernelTrace> {
        btc_trace("bconv_bmma", p, mode, p.c)
    }
}

/// BTC BConv Design-2 (`bmmafmt`): the (N, C) and (C, O) planes reformed
/// into 128x8 FSB bit-tiles so `ldm` is pinned at 128.
pub struct BconvDesign2;

impl BconvScheme for BconvDesign2 {
    fn name(&self) -> &'static str {
        "bconv_fmt"
    }

    fn uses_tensorcores(&self) -> bool {
        true
    }

    fn compute(&self, input: &BitTensor4, filter: &BitTensor4, p: BconvProblem) -> Vec<i32> {
        // the FSB re-tiling only permutes storage within the (N, C) and
        // (C, O) planes; the arithmetic path is identical
        btc_compute(input, filter, p)
    }

    fn traces(&self, p: BconvProblem, mode: IoMode) -> Vec<KernelTrace> {
        btc_trace("bconv_fmt", p, mode, 128)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Engine, RTX2080TI};
    use crate::util::Rng;

    #[test]
    fn exclude_amendment_matches_naive() {
        let mut rng = Rng::new(23);
        let p = BconvProblem { hw: 4, n: 8, c: 128, o: 8, k: 3, stride: 1, pad: 1 };
        let input =
            BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, &mut rng);
        let filter =
            BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, &mut rng);
        assert_eq!(
            BconvDesign1.compute(&input, &filter, p),
            super::super::naive_ref(&input, &filter, p)
        );
    }

    #[test]
    fn corner_outputs_have_reduced_n() {
        // at a corner with 3x3/pad 1, 5 taps are excluded: the output
        // range is bounded by 4*C, not 9*C
        let mut rng = Rng::new(29);
        let p = BconvProblem { hw: 4, n: 8, c: 128, o: 8, k: 3, stride: 1, pad: 1 };
        let input =
            BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, &mut rng);
        let filter =
            BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, &mut rng);
        let out = BconvDesign1.compute(&input, &filter, p);
        // corner (0,0): bound 4*128 = 512
        for v in &out[..8 * 8] {
            assert!(v.abs() <= 512, "corner value {v} out of 4C bound");
            assert_eq!((v % 2), 0, "parity: 4C-2p is even");
        }
    }

    #[test]
    fn fmt_traces_use_fixed_stride() {
        let p = BconvProblem::paper_sweep(1024, 1024);
        for tr in BconvDesign2.traces(p, IoMode::BnnSpecific) {
            for &(ldm, _, _) in &tr.warp.tile_loads {
                assert_eq!(ldm, 128);
            }
        }
        let tr1 = &BconvDesign1.traces(p, IoMode::BnnSpecific)[0];
        assert_eq!(tr1.warp.tile_loads[0].0, 1024);
    }

    #[test]
    fn stride2_halves_output_work() {
        let e = Engine::new(&RTX2080TI);
        let p1 = BconvProblem::paper_sweep(256, 256);
        let mut p2 = p1;
        p2.stride = 2;
        let t1 = super::super::simulate(&e, &BconvDesign2, p1, IoMode::BnnSpecific);
        let t2 = super::super::simulate(&e, &BconvDesign2, p2, IoMode::BnnSpecific);
        assert!(t2 < t1 / 2.0, "stride2 {t2} vs stride1 {t1}");
    }
}
