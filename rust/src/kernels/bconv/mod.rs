//! Bit-convolution schemes (§5.3, Figs 20–23).
//!
//! Problem convention: activations in HWNC layout packed along C,
//! filters in KKOC (O-major per tap, packed along C), output
//! (OH, OW, N, O) i32 — the +/-1 cross-correlation where padded taps are
//! *excluded* (the paper's amendment for the bit-padding problem).

pub mod baselines;
pub mod bstc;
pub mod btc;

use crate::bitops::{BitTensor4, TensorLayout};
use crate::sim::{Engine, KernelTrace};

use super::IoMode;

/// One BConv instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BconvProblem {
    /// input height == width
    pub hw: usize,
    /// batch
    pub n: usize,
    /// input channels
    pub c: usize,
    /// output channels
    pub o: usize,
    /// filter height == width
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl BconvProblem {
    /// The Figs 20–23 sweep point: batch=16, input 64x64, 3x3, stride 1.
    pub fn paper_sweep(c: usize, o: usize) -> BconvProblem {
        BconvProblem { hw: 64, n: 16, c, o, k: 3, stride: 1, pad: 1 }
    }

    pub fn out_hw(&self) -> usize {
        (self.hw + 2 * self.pad - self.k) / self.stride + 1
    }

    /// +/-1 MAC ops (interior-point count; the TOPS numerator).
    pub fn ops(&self) -> f64 {
        2.0 * (self.out_hw() * self.out_hw() * self.n * self.o) as f64
            * (self.k * self.k * self.c) as f64
    }

    pub fn input_bytes(&self) -> f64 {
        (self.hw * self.hw * self.n * self.c / 8) as f64
    }

    pub fn filter_bytes(&self) -> f64 {
        (self.k * self.k * self.c * self.o / 8) as f64
    }

    pub fn out_elems(&self) -> usize {
        self.out_hw() * self.out_hw() * self.n * self.o
    }
}

/// A BConv scheme: functional algorithm + timing trace.
pub trait BconvScheme {
    fn name(&self) -> &'static str;

    fn supports(&self, p: BconvProblem, mode: IoMode) -> bool {
        let _ = mode;
        p.n % 8 == 0 && p.o % 8 == 0 && p.c % 128 == 0
    }

    /// Bit-exact +/-1 cross-correlation with excluded padding.
    /// input: HWNC packed; filter: KKOC packed. Output (OH,OW,N,O) i32.
    fn compute(&self, input: &BitTensor4, filter: &BitTensor4, p: BconvProblem) -> Vec<i32>;

    fn traces(&self, p: BconvProblem, mode: IoMode) -> Vec<KernelTrace>;

    fn uses_tensorcores(&self) -> bool;
}

/// Simulated wall time (seconds).
pub fn simulate(engine: &Engine, s: &dyn BconvScheme, p: BconvProblem, mode: IoMode) -> f64 {
    s.traces(p, mode)
        .iter()
        .map(|t| engine.cost(t).total_secs)
        .sum()
}

/// Simulated TOPS.
pub fn simulate_tops(engine: &Engine, s: &dyn BconvScheme, p: BconvProblem, mode: IoMode) -> f64 {
    p.ops() / simulate(engine, s, p, mode) / 1e12
}

/// Naive reference (the Listing-6 semantics, scalar form).
pub fn naive_ref(input: &BitTensor4, filter: &BitTensor4, p: BconvProblem) -> Vec<i32> {
    assert_eq!(input.layout, TensorLayout::Hwnc);
    assert_eq!(filter.layout, TensorLayout::Kkoc);
    let [h, w, n, c] = input.dims;
    let [kh, kw, o, c2] = filter.dims;
    assert_eq!(c, c2);
    assert_eq!(c, p.c);
    let ohw = p.out_hw();
    let mut out = vec![0i32; ohw * ohw * n * o];
    for op in 0..ohw {
        for oq in 0..ohw {
            for r in 0..kh {
                for s in 0..kw {
                    let i = (op * p.stride + r) as isize - p.pad as isize;
                    let j = (oq * p.stride + s) as isize - p.pad as isize;
                    if i < 0 || i >= h as isize || j < 0 || j >= w as isize {
                        continue; // excluded tap
                    }
                    let (i, j) = (i as usize, j as usize);
                    for ni in 0..n {
                        let a = input.inner(i, j, ni);
                        for oi in 0..o {
                            let b = filter.inner(r, s, oi);
                            out[((op * ohw + oq) * n + ni) * o + oi] +=
                                crate::bitops::pack::pm1_dot(a, b, c);
                        }
                    }
                }
            }
        }
    }
    out
}

/// Pre/post kernels for the General protocol: binarize + relayout the
/// fp32 NHWC input into packed HWNC, and binarize the filter.
pub fn with_general_io(core: Vec<KernelTrace>, p: BconvProblem) -> Vec<KernelTrace> {
    let in_elems = p.hw * p.hw * p.n * p.c;
    let fil_elems = p.k * p.k * p.c * p.o;
    let mut v = vec![
        super::bmm::binarize_trace("binarize_input", in_elems),
        super::bmm::binarize_trace("binarize_filter", fil_elems),
    ];
    v.extend(core);
    v
}

/// All Figs 20–23 schemes, legend order.
pub fn all_schemes() -> Vec<Box<dyn BconvScheme>> {
    vec![
        Box::new(baselines::CudnnBase),
        Box::new(baselines::CudnnFast),
        Box::new(bstc::BstcBconv::new(32)),
        Box::new(bstc::BstcBconv::new(64)),
        Box::new(btc::BconvDesign1),
        Box::new(btc::BconvDesign2),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RTX2080TI;
    use crate::util::Rng;

    fn rand_case(rng: &mut Rng, p: BconvProblem) -> (BitTensor4, BitTensor4) {
        let input = BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, rng);
        let filter =
            BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, rng);
        (input, filter)
    }

    #[test]
    fn all_schemes_match_naive_ref() {
        let mut rng = Rng::new(17);
        for p in [
            BconvProblem { hw: 6, n: 8, c: 128, o: 8, k: 3, stride: 1, pad: 1 },
            BconvProblem { hw: 8, n: 8, c: 128, o: 16, k: 3, stride: 2, pad: 1 },
            BconvProblem { hw: 5, n: 8, c: 128, o: 8, k: 3, stride: 1, pad: 0 },
        ] {
            let (input, filter) = rand_case(&mut rng, p);
            let want = naive_ref(&input, &filter, p);
            for s in all_schemes() {
                if !s.supports(p, IoMode::General) {
                    continue;
                }
                assert_eq!(
                    s.compute(&input, &filter, p),
                    want,
                    "scheme {} disagrees on {:?}",
                    s.name(),
                    p
                );
            }
        }
    }

    #[test]
    fn fsb_bconv_fastest_at_large_channels() {
        // Figs 20–23: the FSB-format design dominates for C=O >= 512
        let e = Engine::new(&RTX2080TI);
        for c in [512usize, 1024, 2048] {
            let p = BconvProblem::paper_sweep(c, c);
            let times: Vec<(String, f64)> = all_schemes()
                .iter()
                .map(|s| {
                    (s.name().to_string(), simulate(&e, s.as_ref(), p, IoMode::General))
                })
                .collect();
            let best = times
                .iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            assert_eq!(best.0, "bconv_fmt", "c={c}: times {times:?}");
        }
    }

    #[test]
    fn design1_relative_penalty_smallest_at_384() {
        // §7.3 (ii): at C=O=384 Design-1 profits from ldm=384 being a
        // fast stride: its gap to the FSB design must be clearly smaller
        // than at the conflicted strides 512/1024 (and larger than the
        // exact tie at 128).
        let e = Engine::new(&RTX2080TI);
        let ratio = |c: usize| {
            let p = BconvProblem::paper_sweep(c, c);
            simulate(&e, &btc::BconvDesign1, p, IoMode::General)
                / simulate(&e, &btc::BconvDesign2, p, IoMode::General)
        };
        let (r384, r512, r1024) = (ratio(384), ratio(512), ratio(1024));
        assert!(r384 < r512 && r384 < r1024, "r384 {r384} r512 {r512} r1024 {r1024}");
        assert!(r384 < 1.7, "r384 {r384}");
    }

    #[test]
    fn equivalent_at_128_channels() {
        // §7.3 (i): when C=O=128 the two BTC designs coincide
        let e = Engine::new(&RTX2080TI);
        let p = BconvProblem::paper_sweep(128, 128);
        let d1 = simulate(&e, &btc::BconvDesign1, p, IoMode::General);
        let d2 = simulate(&e, &btc::BconvDesign2, p, IoMode::General);
        assert!((d1 - d2).abs() / d2 < 1e-6, "d1 {d1} != fmt {d2}");
    }
}
