//! aarch64 NEON popcount inner kernel (the `Neon` engine).
//!
//! NEON's popcount primitive is `cnt` (per-*byte* counts), so the
//! kernel XORs 128-bit vectors, byte-popcounts them, and accumulates
//! the byte counts across an 8-vector block before one widening
//! horizontal add: each u8 lane sums at most 8 counts of <= 8, i.e.
//! <= 64, so the lanes cannot wrap before `vaddlvq_u8` widens them.

use crate::bitops::pack64::lane_pairs;
use core::arch::aarch64::*;

/// `popc(a ^ b)` via `cnt` + widening horizontal add, in blocks of
/// 8 q-registers (16 u64 words), scalar remainder.
///
/// # Safety
///
/// The caller must have verified the `neon` CPU feature via
/// `is_aarch64_feature_detected!` (NEON is architecturally mandatory
/// on aarch64, but the uniform dispatch contract checks anyway).
#[target_feature(enable = "neon")]
pub unsafe fn xor_popc_neon(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let (lanes, ra, rb) = lane_pairs::<16>(a, b);
    let mut acc = 0u32;
    for (x, y) in lanes {
        let mut bytes = vdupq_n_u8(0);
        for v in 0..8 {
            let vx = vld1q_u64(x.as_ptr().add(2 * v));
            let vy = vld1q_u64(y.as_ptr().add(2 * v));
            let xo = veorq_u64(vx, vy);
            bytes = vaddq_u8(bytes, vcntq_u8(vreinterpretq_u8_u64(xo)));
        }
        acc += vaddlvq_u8(bytes) as u32;
    }
    for (x, y) in ra.iter().zip(rb) {
        acc += (x ^ y).count_ones();
    }
    acc
}
