//! Explicit-SIMD popcount host kernels (`Scheme::Simd`).
//!
//! The paper's thesis is that bit-level parallelism pays only when the
//! kernel is co-designed for the hardware's widest bit operation; on
//! the host that operation is the vector (or at least hardware-scalar)
//! popcount.  This module provides the inner line kernels behind a
//! [`PopcountEngine`] chosen **once** at registry construction by
//! runtime feature detection:
//!
//! * `Avx512` — `vpopcntdq`: 8 u64 popcounts per instruction
//!   (`avx512f` + `avx512vpopcntdq`);
//! * `Avx2` — hardware scalar `popcnt` unrolled over 4-word lanes
//!   (AVX2 itself has no vector popcount; the detection requires
//!   `avx2 && popcnt` to mark a wide modern core);
//! * `Neon` — `cnt` byte-popcount + widening horizontal add on
//!   aarch64;
//! * `Portable` — delegates to [`xor_popc64`]'s autovectorizable u64
//!   unroll, available on every host (and under miri), keeping the
//!   backend registerable and bit-exact-testable anywhere.
//!
//! Selection order: `TCBNN_SIMD=portable|avx2|avx512|neon` forces an
//! engine **if it is available on this host** (an unavailable or
//! unknown value falls back to detection — which is how the CI matrix
//! forces `avx512` on runners that may not have it); otherwise the
//! widest available engine wins.  All engines compute the same exact
//! integer popcount, so every dispatch path is bit-identical — CI runs
//! the full test suite once per forced engine to prove it.
//!
//! The blocked BMM/BConv structure (MC/NC/KC cache blocking, bit-
//! im2row lowering, NUMA-sharded row bands) is shared with the
//! fastpath via `fastpath::bmm::popc_lines_with` /
//! `fastpath::bconv::bconv_into_with`; only the KC-word inner product
//! changes.

#[cfg(target_arch = "aarch64")]
pub mod neon;
#[cfg(target_arch = "x86_64")]
pub mod x86;

use crate::bitops::pack64::{xor_popc64, BitMatrix64};
use crate::bitops::{BitMatrix, BitTensor4, TensorLayout};
use crate::kernels::bconv::BconvProblem;
use crate::kernels::fastpath::bconv::{self, FastConvFilter};
use crate::kernels::fastpath::bmm;

/// The environment variable that forces an engine (`portable`, `avx2`,
/// `avx512`, `neon`); unknown or unavailable values fall back to
/// detection.
pub const ENGINE_ENV: &str = "TCBNN_SIMD";

/// One popcount inner-kernel implementation.
///
/// All variants exist on every architecture so names always parse; an
/// engine may only be *executed* where [`is_available`] holds — the
/// dispatcher falls back to the portable kernel for foreign variants,
/// and `xor_popc` debug-asserts availability.
///
/// [`is_available`]: PopcountEngine::is_available
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PopcountEngine {
    /// Autovectorized u64 `count_ones` (always available).
    Portable,
    /// x86-64 hardware `popcnt` over 4-word lanes.
    Avx2,
    /// x86-64 `vpopcntdq` over 8-word vectors.
    Avx512,
    /// aarch64 `cnt` + widening horizontal add.
    Neon,
}

impl PopcountEngine {
    /// Every variant, in preference order (widest first).
    pub fn all() -> [PopcountEngine; 4] {
        [
            PopcountEngine::Avx512,
            PopcountEngine::Avx2,
            PopcountEngine::Neon,
            PopcountEngine::Portable,
        ]
    }

    /// Stable lowercase name (the `TCBNN_SIMD` vocabulary).
    pub fn name(&self) -> &'static str {
        match self {
            PopcountEngine::Portable => "portable",
            PopcountEngine::Avx2 => "avx2",
            PopcountEngine::Avx512 => "avx512",
            PopcountEngine::Neon => "neon",
        }
    }

    /// Inverse of [`name`](PopcountEngine::name), case-insensitive.
    pub fn from_name(s: &str) -> Option<PopcountEngine> {
        PopcountEngine::all().into_iter().find(|e| e.name().eq_ignore_ascii_case(s))
    }

    /// Whether this engine can execute on the current host.
    pub fn is_available(&self) -> bool {
        match self {
            PopcountEngine::Portable => true,
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            PopcountEngine::Avx2 => {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("popcnt")
            }
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            PopcountEngine::Avx512 => {
                std::arch::is_x86_feature_detected!("avx512f")
                    && std::arch::is_x86_feature_detected!("avx512vpopcntdq")
            }
            #[cfg(all(target_arch = "aarch64", not(miri)))]
            PopcountEngine::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Every engine executable on this host (always contains
    /// `Portable`), in preference order.
    pub fn available() -> Vec<PopcountEngine> {
        PopcountEngine::all().into_iter().filter(|e| e.is_available()).collect()
    }

    /// The widest available engine.
    pub fn auto() -> PopcountEngine {
        PopcountEngine::all()
            .into_iter()
            .find(|e| e.is_available())
            .unwrap_or(PopcountEngine::Portable)
    }

    /// Engine selection with an optional override (the `TCBNN_SIMD`
    /// contract, factored out of env access for testability): a
    /// recognized **and available** engine name wins; anything else
    /// falls back to [`auto`](PopcountEngine::auto).
    pub fn select(overridden: Option<&str>) -> PopcountEngine {
        match overridden.and_then(PopcountEngine::from_name) {
            Some(e) if e.is_available() => e,
            _ => PopcountEngine::auto(),
        }
    }

    /// One-shot detection honoring `TCBNN_SIMD` — what
    /// `SimdBackend::detect()` calls at registry construction.
    pub fn detect() -> PopcountEngine {
        PopcountEngine::select(std::env::var(ENGINE_ENV).ok().as_deref())
    }

    /// `popc(a ^ b)` over two equal-length packed lines, dispatched to
    /// this engine's kernel.  Exact for every engine; foreign variants
    /// (and anything under miri) run the portable kernel.
    #[inline]
    pub fn xor_popc(&self, a: &[u64], b: &[u64]) -> u32 {
        debug_assert!(self.is_available(), "dispatching unavailable engine {self:?}");
        match self {
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            // SAFETY: is_available() checked the exact CPU features the
            // target_feature attributes of these kernels require; the
            // debug_assert above (and construction via detect/select/
            // available) keeps unavailable variants out of here.
            PopcountEngine::Avx2 => unsafe { x86::xor_popc_popcnt4(a, b) },
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            // SAFETY: as above (avx512f + avx512vpopcntdq detected).
            PopcountEngine::Avx512 => unsafe { x86::xor_popc_vpopcntdq(a, b) },
            #[cfg(all(target_arch = "aarch64", not(miri)))]
            // SAFETY: as above (neon detected).
            PopcountEngine::Neon => unsafe { neon::xor_popc_neon(a, b) },
            #[allow(unreachable_patterns)]
            _ => xor_popc64(a, b),
        }
    }
}

/// Allocating Eq-2 BMM through `engine` (the `fastpath::bmm::bmm`
/// convention: `a` row-major, `b` column-major); benches and tests.
pub fn bmm(a: &BitMatrix, b: &BitMatrix, threads: usize, engine: PopcountEngine) -> Vec<i32> {
    let a64 = BitMatrix64::from_bitmatrix(a);
    let b64 = BitMatrix64::from_bitmatrix(b);
    assert_eq!(a.cols, b.rows, "inner dimensions");
    assert_eq!(a64.words_per_line, b64.words_per_line, "operands must pack the same K width");
    let mut out = vec![0i32; a.rows * b.cols];
    let dot = move |x: &[u64], y: &[u64]| engine.xor_popc(x, y);
    bmm::dot_lines_with(
        &a64.data,
        &b64.data,
        a64.words_per_line,
        a.rows,
        b.cols,
        a.cols,
        &mut out,
        threads,
        &dot,
    );
    out
}

/// Allocating BConv through `engine` (the `fastpath::bconv::bconv`
/// convention); benches and tests.
pub fn bconv(
    input: &BitTensor4,
    filter: &BitTensor4,
    p: BconvProblem,
    threads: usize,
    engine: PopcountEngine,
) -> Vec<i32> {
    assert_eq!(input.layout, TensorLayout::Hwnc);
    assert_eq!(input.dims, [p.hw, p.hw, p.n, p.c], "input dims");
    let f = FastConvFilter::prepare(filter);
    let mut a64 = vec![0u64; bconv::rows(p) * bconv::row_words(p)];
    let mut out = vec![0i32; bconv::rows(p) * p.o];
    let dot = move |x: &[u64], y: &[u64]| engine.xor_popc(x, y);
    bconv::bconv_into_with(&input.data, p, &f, &mut a64, &mut out, threads, &dot);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::Layout;
    use crate::util::proptest::run_cases;
    use crate::util::Rng;

    #[test]
    fn names_round_trip_and_parse_case_insensitively() {
        for e in PopcountEngine::all() {
            assert_eq!(PopcountEngine::from_name(e.name()), Some(e));
            assert_eq!(PopcountEngine::from_name(&e.name().to_uppercase()), Some(e));
        }
        assert_eq!(PopcountEngine::from_name("sse9"), None);
    }

    #[test]
    fn portable_is_always_available_and_listed_last() {
        assert!(PopcountEngine::Portable.is_available());
        let avail = PopcountEngine::available();
        assert!(!avail.is_empty());
        assert_eq!(*avail.last().unwrap(), PopcountEngine::Portable);
        // auto() is the head of the availability list
        assert_eq!(PopcountEngine::auto(), avail[0]);
        for e in avail {
            assert!(e.is_available());
        }
    }

    #[test]
    fn select_honors_available_overrides_and_ignores_the_rest() {
        // an explicitly requested, available engine wins
        assert_eq!(PopcountEngine::select(Some("portable")), PopcountEngine::Portable);
        for e in PopcountEngine::available() {
            assert_eq!(PopcountEngine::select(Some(e.name())), e);
        }
        // unknown names and absent overrides detect
        assert_eq!(PopcountEngine::select(Some("bogus")), PopcountEngine::auto());
        assert_eq!(PopcountEngine::select(None), PopcountEngine::auto());
        // an unavailable engine name must fall back, not panic: at
        // least one of avx512/neon is foreign on any single host
        for name in ["avx512", "neon", "avx2"] {
            let chosen = PopcountEngine::select(Some(name));
            assert!(chosen.is_available());
        }
    }

    #[test]
    fn every_available_engine_matches_the_portable_popcount() {
        run_cases(81, 60, |rng| {
            let n = 1 + rng.gen_range(200);
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let want = xor_popc64(&a, &b);
            for e in PopcountEngine::available() {
                assert_eq!(e.xor_popc(&a, &b), want, "engine {} at {n} words", e.name());
            }
        });
    }

    #[test]
    fn engines_agree_on_lane_boundary_lengths() {
        // exact multiples of every lane width, plus off-by-one each way
        let mut rng = Rng::new(82);
        for n in [1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 64, 127, 256] {
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let want = xor_popc64(&a, &b);
            for e in PopcountEngine::available() {
                assert_eq!(e.xor_popc(&a, &b), want, "engine {} at {n} words", e.name());
            }
        }
    }

    #[test]
    fn engine_bmm_matches_the_naive_reference() {
        use crate::kernels::bmm::naive_ref;
        run_cases(83, 15, |rng| {
            let m = 1 + rng.gen_range(40);
            let n = 1 + rng.gen_range(40);
            let k = 1 + rng.gen_range(300);
            let a = BitMatrix::random(m, k, Layout::RowMajor, rng);
            let b = BitMatrix::random(k, n, Layout::ColMajor, rng);
            let want = naive_ref(&a, &b);
            for e in PopcountEngine::available() {
                assert_eq!(bmm(&a, &b, 2, e), want, "engine {} {m}x{n}x{k}", e.name());
            }
        });
    }

    #[test]
    fn engine_bconv_matches_the_fastpath() {
        use crate::kernels::fastpath;
        let mut rng = Rng::new(84);
        let p = BconvProblem { hw: 8, n: 3, c: 33, o: 5, k: 3, stride: 1, pad: 1 };
        let input = BitTensor4::random([p.hw, p.hw, p.n, p.c], TensorLayout::Hwnc, &mut rng);
        let filter = BitTensor4::random([p.k, p.k, p.o, p.c], TensorLayout::Kkoc, &mut rng);
        let want = fastpath::bconv::bconv(&input, &filter, p, 2);
        for e in PopcountEngine::available() {
            assert_eq!(bconv(&input, &filter, p, 2, e), want, "engine {}", e.name());
        }
    }
}
