//! x86-64 popcount inner kernels (the `Avx2` and `Avx512` engines).
//!
//! Both consume the [`lane_pairs`] shape: whole `L`-word lanes with a
//! scalar `count_ones` remainder, so every line length is exact.

use crate::bitops::pack64::lane_pairs;
use core::arch::x86_64::*;

/// `popc(a ^ b)` with the hardware `popcnt` instruction unrolled over
/// 4-word lanes — the `Avx2` engine.  AVX2 itself has no vector
/// popcount; on AVX2-class cores the win over the portable kernel is
/// that `popcnt` replaces the compiler's SWAR bithack under the
/// default x86-64 target baseline.
///
/// # Safety
///
/// The caller must have verified the `popcnt` CPU feature (the
/// dispatcher checks `avx2 && popcnt` via `is_x86_feature_detected!`).
#[target_feature(enable = "popcnt")]
pub unsafe fn xor_popc_popcnt4(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let (lanes, ra, rb) = lane_pairs::<4>(a, b);
    let mut acc: i64 = 0;
    for (x, y) in lanes {
        acc += _popcnt64((x[0] ^ y[0]) as i64) as i64;
        acc += _popcnt64((x[1] ^ y[1]) as i64) as i64;
        acc += _popcnt64((x[2] ^ y[2]) as i64) as i64;
        acc += _popcnt64((x[3] ^ y[3]) as i64) as i64;
    }
    let mut tail = 0u32;
    for (x, y) in ra.iter().zip(rb) {
        tail += (x ^ y).count_ones();
    }
    acc as u32 + tail
}

/// `popc(a ^ b)` with `vpopcntdq` over 8-word vectors — the `Avx512`
/// engine.  Per-lane u64 accumulation, one horizontal reduce at the
/// end.
///
/// # Safety
///
/// The caller must have verified the `avx512f` and `avx512vpopcntdq`
/// CPU features via `is_x86_feature_detected!`.
#[target_feature(enable = "avx512f,avx512vpopcntdq")]
pub unsafe fn xor_popc_vpopcntdq(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let (lanes, ra, rb) = lane_pairs::<8>(a, b);
    let mut vacc = _mm512_setzero_si512();
    for (x, y) in lanes {
        let vx = _mm512_set_epi64(
            x[7] as i64,
            x[6] as i64,
            x[5] as i64,
            x[4] as i64,
            x[3] as i64,
            x[2] as i64,
            x[1] as i64,
            x[0] as i64,
        );
        let vy = _mm512_set_epi64(
            y[7] as i64,
            y[6] as i64,
            y[5] as i64,
            y[4] as i64,
            y[3] as i64,
            y[2] as i64,
            y[1] as i64,
            y[0] as i64,
        );
        let xo = _mm512_xor_si512(vx, vy);
        vacc = _mm512_add_epi64(vacc, _mm512_popcnt_epi64(xo));
    }
    let mut acc = _mm512_reduce_add_epi64(vacc) as u32;
    for (x, y) in ra.iter().zip(rb) {
        acc += (x ^ y).count_ones();
    }
    acc
}
