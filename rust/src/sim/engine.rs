//! The timing engine: occupancy + roofline composition of a KernelTrace
//! on a GpuModel.
//!
//! The model is analytic (not event-driven): a kernel's duration is the
//! maximum over resource bounds — tensor-core issue, INTU/SFU issue,
//! DRAM bandwidth, and the latency-bound pipeline-fill term — plus
//! fixed launch and cooperative-sync overheads.  This is the classic
//! GPU "max-of-rooflines + startup" form; every term is driven by the
//! §4-calibrated mechanism models.

use super::config::{GpuModel, MemSpace};
use super::tensorcore as tc;
use super::trace::KernelTrace;
use super::wmma;

/// Per-resource cycle bounds for one launch (for reporting/debugging).
#[derive(Clone, Debug, Default)]
pub struct CostBreakdown {
    pub active_warps_per_sm: usize,
    pub warp_serial_cycles: f64,
    pub tcu_cycles: f64,
    pub intu_cycles: f64,
    pub sfu_cycles: f64,
    pub fpu_cycles: f64,
    pub dram_cycles: f64,
    pub latency_cycles: f64,
    pub sync_cycles: f64,
    pub total_cycles: f64,
    pub total_secs: f64,
    /// which bound won ("tcu", "dram", ...)
    pub bottleneck: &'static str,
}

/// The simulator facade.
#[derive(Clone, Debug)]
pub struct Engine {
    pub gpu: GpuModel,
}

impl Engine {
    pub fn new(gpu: &GpuModel) -> Engine {
        Engine { gpu: gpu.clone() }
    }

    /// Warps resident per SM given the trace's occupancy limiters.
    pub fn occupancy(&self, t: &KernelTrace) -> usize {
        let g = &self.gpu;
        let by_warps = g.max_warps_per_sm / t.warps_per_cta.max(1);
        let by_smem = if t.smem_per_cta > 0 {
            g.shared_per_sm / t.smem_per_cta
        } else {
            g.max_ctas_per_sm
        };
        let by_regs = if t.regs_per_thread > 0 {
            g.regs_per_sm / (t.regs_per_thread * t.warps_per_cta * 32)
        } else {
            g.max_ctas_per_sm
        };
        let ctas = by_warps.min(by_smem).min(by_regs).min(g.max_ctas_per_sm).max(1);
        // can't exceed the grid itself (spread over SMs)
        let grid_ctas_per_sm = t.grid_ctas.div_ceil(g.sms).max(1);
        ctas.min(grid_ctas_per_sm) * t.warps_per_cta
    }

    /// One warp's serial (dependency-chain) cycles.
    pub fn warp_serial_cycles(&self, t: &KernelTrace) -> f64 {
        let g = &self.gpu;
        let w = &t.warp;
        let mut cy = 0.0;
        for &(ldm, space, count) in &w.tile_loads {
            if count == 0 {
                continue;
            }
            // memory-level parallelism: the K-loop's next loads issue
            // while the current bmma computes — only the first load pays
            // full latency, the rest stream behind it
            let first = wmma::load_latency(g, ldm, space);
            let stream = match space {
                MemSpace::Global => 40.0,
                MemSpace::Shared => 8.0,
            };
            cy += first + (count as f64 - 1.0) * stream;
        }
        for &(space, count) in &w.tile_stores {
            cy += wmma::store_latency(g, 0, space) * count as f64;
        }
        // bulk loads: one LDG.E.128 round trip per 512B, pipelined
        if w.bulk_load_bytes > 0 {
            let rounds = (w.bulk_load_bytes as f64 / 512.0).ceil();
            cy += g.global_load_base_cycles + (rounds - 1.0) * 8.0;
        }
        if w.bulk_store_bytes > 0 {
            cy += g.global_store_cycles;
        }
        cy += tc::bmma_latency(g, w.bmma_ops, false);
        cy += tc::bmma_latency(g, w.bmma_same_acc_ops, true);
        // issue-bound lane work (assume full pipelining within the warp)
        cy += w.intu_ops as f64 / 32.0;
        cy += w.sfu_ops as f64 / 4.0;
        cy += w.fp_ops as f64 / 32.0;
        cy += w.hmma_fmas as f64 / (2.0 * g.hmma_fma_per_tcu);
        cy += w.int4_macs as f64 / (8.0 * g.hmma_fma_per_tcu);
        cy += w.cta_syncs as f64 * 20.0;
        cy
    }

    /// Memory-hierarchy cycle bound.
    ///
    /// Three levels, all driven by the trace:
    ///
    /// * **L1 filter** — WMMA tile loads hit L1 at a rate set by their
    ///   stride quality (fully-coalesced FSB tiles are dense cache lines
    ///   reused by neighbouring warps; conflicted 32B-aligned strides
    ///   splinter).  The filter degrades toward miss=1 as the kernel's
    ///   unique footprint outgrows cacheability — the §7.2 (I) ">4K
    ///   drop".  Bulk/streaming traffic always passes through.
    /// * **L2 bandwidth** — filtered traffic at `l2_bw_mult` x DRAM BW.
    /// * **DRAM** — compulsory footprint plus the L2-missing fraction of
    ///   the filtered traffic.
    pub fn memory_cycles(&self, t: &KernelTrace) -> f64 {
        let g = &self.gpu;
        let w = &t.warp;
        let total_warps = t.total_warps() as f64;
        let comp = if t.compulsory_bytes > 0.0 {
            t.compulsory_bytes
        } else {
            t.dram_bytes()
        };
        let mut load_fp = if t.load_footprint_bytes > 0.0 {
            t.load_footprint_bytes
        } else {
            comp
        };
        if t.wave_bytes_per_cta > 0.0 {
            load_fp = load_fp.min(t.wave_bytes_per_cta * g.sms as f64);
        }
        // footprint-driven degradation of L1 locality (loads only — the
        // streamed output does not evict operand lines meaningfully)
        let spill = ((load_fp - g.l2_bytes) / (32.0 * g.l2_bytes)).clamp(0.0, 1.0);

        let mut l2_traffic = 0.0f64;
        for &(ldm, space, count) in &w.tile_loads {
            if space == MemSpace::Global {
                let info = super::memory::bit_tile_coalesce(0, ldm);
                let base_miss = match info.issue_cycles {
                    0..=2 => 0.08, // dense 128B lines (FSB / ldm=128)
                    3..=4 => 0.16, // fast strided family (128+256k)
                    _ => 0.40,     // conflicted 32B-aligned strides
                };
                // l1_miss_rate acts as a global scale on the stride-based
                // factors (0.25 = calibrated default; see bench_ablation A4)
                let base_miss = (base_miss * self.gpu.l1_miss_rate / 0.25).min(1.0);
                let miss = base_miss + (1.0 - base_miss) * spill;
                l2_traffic +=
                    info.bytes_moved as f64 * miss * count as f64 * total_warps;
            }
        }
        for &(space, count) in &w.tile_stores {
            if space == MemSpace::Global {
                l2_traffic += (super::wmma::store_bytes_moved() * count) as f64
                    * total_warps;
            }
        }
        l2_traffic += (w.bulk_load_bytes + w.bulk_store_bytes) as f64 * total_warps;
        l2_traffic = l2_traffic.max(comp);

        let l2_cycles = l2_traffic / (g.bytes_per_cycle() * g.l2_bw_mult);
        let l2_miss = if load_fp <= 0.8 * g.l2_bytes {
            0.03
        } else {
            (0.03 + (load_fp - 0.8 * g.l2_bytes) / (4.0 * g.l2_bytes)).min(1.0)
        };
        let dram_bytes = (comp + (l2_traffic - comp) * l2_miss).min(l2_traffic);
        let dram_cycles = dram_bytes / g.bytes_per_cycle();
        l2_cycles.max(dram_cycles)
    }

    /// Shared-memory bandwidth bound (128 B/cycle per SM).
    pub fn shared_cycles(&self, t: &KernelTrace) -> f64 {
        t.shared_bytes_per_warp() * t.total_warps() as f64
            / (128.0 * self.gpu.sms as f64)
    }

    /// Full cost of one kernel trace.
    pub fn cost(&self, t: &KernelTrace) -> CostBreakdown {
        let g = &self.gpu;
        let total_warps = t.total_warps() as f64;
        let active = self.occupancy(t);
        let warp_serial = self.warp_serial_cycles(t);

        // ---- throughput bounds, whole chip ----
        let sms = g.sms as f64;
        let w = &t.warp;
        // NOTE: the same-accumulator stall (+6 cycles) is a per-warp
        // dependency bubble; other resident warps fill the TCU pipeline,
        // so chip-level throughput runs at the pipelined rate for both.
        let tcu = ((w.bmma_ops + w.bmma_same_acc_ops) as f64
            / tc::bmma_rate_per_sm(g, false)
            + w.hmma_fmas as f64 / tc::hmma_fma_rate_per_sm(g)
            + w.int4_macs as f64 / tc::int4_mac_rate_per_sm(g))
            * total_warps
            / sms;
        let intu = w.intu_ops as f64 * total_warps / tc::intu_rate_per_sm(g) / sms;
        let sfu = w.sfu_ops as f64 * total_warps / tc::sfu_rate_per_sm(g) / sms;
        let fpu = w.fp_ops as f64 * total_warps / (32.0 * g.subcores as f64) / sms;
        // WMMA loads also occupy LSU issue slots; fold into dram bound.
        let dram = self.memory_cycles(t);
        let shared = self.shared_cycles(t);

        // ---- latency bound: rounds of resident warps, each round's
        // pipeline must fill once; steady-state is throughput-bound ----
        let rounds = (total_warps / (active as f64 * sms)).ceil().max(1.0);
        // With `active` warps interleaving, per-warp serial latency is
        // hidden up to the active-warp count:
        let latency = rounds * warp_serial / (active as f64).min(warp_serial.max(1.0));

        let sync = t.coop_syncs as f64 * g.coop_sync_cycles;
        let launch_cycles = t.launches as f64 * g.launch_overhead_s * g.clock_hz;

        let (mut bottleneck, mut peak) = ("latency", latency);
        for (n, v) in [
            ("tcu", tcu),
            ("intu", intu),
            ("sfu", sfu),
            ("fpu", fpu),
            ("dram", dram),
            ("shared", shared),
        ] {
            if v > peak {
                peak = v;
                bottleneck = n;
            }
        }
        // startup: first warp's serial chain isn't hidden
        let total = peak + warp_serial + sync + launch_cycles;
        CostBreakdown {
            active_warps_per_sm: active,
            warp_serial_cycles: warp_serial,
            tcu_cycles: tcu,
            intu_cycles: intu,
            sfu_cycles: sfu,
            fpu_cycles: fpu,
            dram_cycles: dram,
            latency_cycles: latency,
            sync_cycles: sync,
            total_cycles: total,
            total_secs: g.secs(total),
            bottleneck,
        }
    }

    /// Cost of a sequence of dependent launches/phases (e.g. the layers
    /// of a fused BNN kernel separated by cooperative syncs).
    pub fn cost_seq(&self, traces: &[KernelTrace]) -> f64 {
        traces.iter().map(|t| self.cost(t).total_secs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{MemSpace, RTX2080TI};
    use crate::sim::trace::KernelTrace;

    fn bmm_like_trace(tiles: usize, ldm: usize) -> KernelTrace {
        let mut t = KernelTrace::new("test");
        t.grid_ctas = tiles;
        t.warps_per_cta = 2;
        t.warp.load_tiles(ldm, MemSpace::Global, 16);
        t.warp.bmma_same_acc_ops = 8;
        t.warp.store_tiles(MemSpace::Global, 1);
        t
    }

    #[test]
    fn more_work_more_cycles() {
        let e = Engine::new(&RTX2080TI);
        let small = e.cost(&bmm_like_trace(64, 128)).total_cycles;
        let big = e.cost(&bmm_like_trace(4096, 128)).total_cycles;
        assert!(big > small);
    }

    #[test]
    fn fast_stride_beats_slow_stride() {
        let e = Engine::new(&RTX2080TI);
        let fast = e.cost(&bmm_like_trace(2048, 128)).total_secs;
        let slow = e.cost(&bmm_like_trace(2048, 1024)).total_secs;
        assert!(fast < slow, "fast {fast} vs slow {slow}");
    }

    #[test]
    fn occupancy_respects_smem() {
        let e = Engine::new(&RTX2080TI);
        let mut t = KernelTrace::new("t");
        t.grid_ctas = 10_000;
        t.warps_per_cta = 2;
        t.smem_per_cta = 32 * 1024; // only 2 CTAs fit
        assert_eq!(e.occupancy(&t), 4);
        t.smem_per_cta = 0;
        assert_eq!(e.occupancy(&t), 16 * 2); // CTA-limit bound
    }

    #[test]
    fn occupancy_small_grid() {
        let e = Engine::new(&RTX2080TI);
        let mut t = KernelTrace::new("t");
        t.grid_ctas = 68; // one per SM
        t.warps_per_cta = 4;
        assert_eq!(e.occupancy(&t), 4);
    }

    #[test]
    fn sync_and_launch_overhead_counted() {
        let e = Engine::new(&RTX2080TI);
        let mut t = bmm_like_trace(64, 128);
        let base = e.cost(&t).total_secs;
        t.coop_syncs = 10;
        let with_sync = e.cost(&t).total_secs;
        assert!(with_sync > base);
        t.launches = 3;
        assert!(e.cost(&t).total_secs > with_sync);
    }

    #[test]
    fn bottleneck_labels() {
        let e = Engine::new(&RTX2080TI);
        let mut t = KernelTrace::new("mem");
        t.grid_ctas = 100_000;
        t.warps_per_cta = 2;
        t.warp.bulk_load_bytes = 1 << 16;
        assert_eq!(e.cost(&t).bottleneck, "dram");
    }
}
