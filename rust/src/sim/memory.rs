//! Warp-level memory access model: sector coalescing and the L1
//! dual-port sector interleave (§4.1).
//!
//! The mechanism, implemented literally from the paper's explanation:
//! the Turing L1 data cache is split into two sectors with independent
//! ports, interleaving the address space at a 32-byte step.  A warp-wide
//! access is decomposed into 32-byte sectors; sectors mapping to the same
//! port serialize, sectors on different ports dual-issue.  Strides that
//! are an odd multiple of 16 bytes (ldm = 128 + 256k bits for bit tiles)
//! spread consecutive tile rows across both port phases; 32-byte-aligned
//! strides pile rows onto one port and serialize.

/// One warp-lane memory request.
#[derive(Clone, Copy, Debug)]
pub struct LaneAccess {
    pub byte_addr: usize,
    pub bytes: usize,
}

pub const SECTOR_BYTES: usize = 32;

/// Decompose a warp's lane accesses into distinct 32B sectors.
pub fn sectors(accesses: &[LaneAccess]) -> Vec<usize> {
    let mut out: Vec<usize> = accesses
        .iter()
        .flat_map(|a| {
            let first = a.byte_addr / SECTOR_BYTES;
            let last = (a.byte_addr + a.bytes.max(1) - 1) / SECTOR_BYTES;
            first..=last
        })
        .collect();
    out.sort_unstable();
    out.dedup();
    out
}

/// L1 port of a sector: the 32B interleave step means consecutive
/// sectors alternate ports.
#[inline]
pub fn sector_port(sector: usize) -> usize {
    sector % 2
}

/// Summary of a warp-wide access after coalescing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CoalesceInfo {
    /// distinct 32B sectors touched
    pub sectors: usize,
    /// cycles needed to issue all sectors through the two ports
    /// (max over ports of sectors on that port)
    pub issue_cycles: usize,
    /// bytes actually moved (sectors * 32; over-fetch shows up here)
    pub bytes_moved: usize,
}

/// Coalesce a warp's accesses and compute the issue schedule.
pub fn coalesce(accesses: &[LaneAccess]) -> CoalesceInfo {
    let secs = sectors(accesses);
    let p0 = secs.iter().filter(|&&s| sector_port(s) == 0).count();
    let p1 = secs.len() - p0;
    CoalesceInfo {
        sectors: secs.len(),
        issue_cycles: p0.max(p1).max(1),
        bytes_moved: secs.len() * SECTOR_BYTES,
    }
}

/// Lane accesses for a WMMA bit-tile load (§4.1's mapping): 8 thread
/// groups of 4 lanes, group g covers 128-bit row g, each lane one 4-byte
/// word.  `ldm_bits` is the row stride in elements (bits), `base` the
/// tile's byte offset.
pub fn bit_tile_accesses(base: usize, ldm_bits: usize) -> Vec<LaneAccess> {
    let stride_bytes = ldm_bits / 8;
    (0..32)
        .map(|lane| {
            let group = lane / 4; // row
            let word = lane % 4;
            LaneAccess { byte_addr: base + group * stride_bytes + word * 4, bytes: 4 }
        })
        .collect()
}

/// Coalescing for a WMMA bit-tile load, including the dual-port L1
/// sector-interleave conflict of §4.1.
///
/// Mechanism (Jia et al.'s dissection + the paper's own explanation):
/// the 8 thread groups issue their 128-bit rows in beats of two groups
/// spaced two rows apart — (0,2), (1,3), (4,6), (5,7).  The L1 is split
/// into two 32-byte-interleaved sector ports (`port = (addr/32) % 2`);
/// a beat whose two rows land on the same port serializes.  The net
/// effect: strides that are an odd multiple of 16 B (`ldm = 128+256k`
/// bits) stay conflict-free, 32-byte-aligned strides (`ldm = 256k`)
/// conflict on every beat — exactly the Figs 2/4 pattern.
pub fn bit_tile_coalesce(base: usize, ldm_bits: usize) -> CoalesceInfo {
    let accesses = bit_tile_accesses(base, ldm_bits);
    let base_info = coalesce(&accesses);
    let stride = ldm_bits / 8;
    let row_sector = |r: usize| (base + r * stride) / SECTOR_BYTES;
    let mut conflicts = 0usize;
    for r in [0usize, 1, 4, 5] {
        let (s0, s1) = (row_sector(r), row_sector(r + 2));
        if s0 != s1 && sector_port(s0) == sector_port(s1) {
            conflicts += 1;
        }
    }
    CoalesceInfo {
        sectors: base_info.sectors,
        issue_cycles: base_info.sectors.div_ceil(2) + conflicts,
        bytes_moved: base_info.bytes_moved,
    }
}

/// Lane accesses for a WMMA int-tile (8x8 i32) store: row-major, two
/// consecutive elements per lane encoded as one 8-byte STG.E.64 (§4.2).
pub fn int_tile_accesses(base: usize, ldm_elems: usize) -> Vec<LaneAccess> {
    (0..32)
        .map(|lane| {
            let row = lane / 4;
            let pair = lane % 4;
            LaneAccess {
                byte_addr: base + row * ldm_elems * 4 + pair * 8,
                bytes: 8,
            }
        })
        .collect()
}

/// Lane accesses for a 128-bit-per-lane vectorized load (LDG.E.128,
/// Design-2's staging path): 32 lanes x 16B contiguous.
pub fn vec128_accesses(base: usize) -> Vec<LaneAccess> {
    (0..32)
        .map(|lane| LaneAccess { byte_addr: base + lane * 16, bytes: 16 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ldm128_is_fully_coalesced() {
        // 8 rows x 16B at 16B stride = 128 contiguous bytes = 4 sectors,
        // 2 per port, no paired-beat conflicts -> 2 issue cycles.
        let info = bit_tile_coalesce(0, 128);
        assert_eq!(info.sectors, 4);
        assert_eq!(info.issue_cycles, 2);
        assert_eq!(info.bytes_moved, 128);
    }

    #[test]
    fn ldm256_port_conflicts() {
        // 32B stride: paired rows (r, r+2) are 64B apart — same port on
        // every beat -> 4 conflict cycles on top of 4 issue cycles.
        let info = bit_tile_coalesce(0, 256);
        assert_eq!(info.sectors, 8);
        assert_eq!(info.issue_cycles, 8, "every beat port-conflicts");
    }

    #[test]
    fn ldm384_balances_ports() {
        // 48B stride (odd multiple of 16B): paired rows are 96B apart —
        // opposite ports, conflict-free.
        let info = bit_tile_coalesce(0, 384);
        assert_eq!(info.sectors, 8);
        assert_eq!(info.issue_cycles, 4, "sectors split across ports");
    }

    #[test]
    fn fast_stride_family_128_plus_256k() {
        // §4.1: ldm = 128+256k (384, 640, 896) all behave well.
        let base = bit_tile_coalesce(0, 384).issue_cycles;
        for ldm in [640, 896, 1152] {
            let c = bit_tile_coalesce(0, ldm);
            assert_eq!(c.issue_cycles, base, "ldm={ldm}");
        }
        // and the 32B-aligned family is strictly worse
        for ldm in [256, 512, 768, 1024] {
            let c = bit_tile_coalesce(0, ldm);
            assert!(c.issue_cycles > base, "ldm={ldm}");
        }
    }

    #[test]
    fn int_tile_store_is_8_sectors() {
        let info = coalesce(&int_tile_accesses(0, 8));
        // 8 rows x 32B = 256B contiguous
        assert_eq!(info.sectors, 8);
        assert_eq!(info.bytes_moved, 256);
    }

    #[test]
    fn vec128_is_contiguous_512b() {
        let info = coalesce(&vec128_accesses(0));
        assert_eq!(info.sectors, 16);
        assert_eq!(info.issue_cycles, 8);
        assert_eq!(info.bytes_moved, 512);
    }

    #[test]
    fn overfetch_counts_whole_sectors() {
        // a single misaligned 4-byte access still moves a 32B sector
        let info = coalesce(&[LaneAccess { byte_addr: 30, bytes: 4 }]);
        assert_eq!(info.sectors, 2);
        assert_eq!(info.bytes_moved, 64);
    }
}
