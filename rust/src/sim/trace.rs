//! Kernel event traces: the interface between kernel implementations and
//! the timing engine.
//!
//! A kernel implementation (rust/src/kernels/*) describes one launch as a
//! `KernelTrace`: grid/CTA geometry plus the aggregate per-warp work.
//! Events carry the *actual* strides and accumulator-reuse behaviour of
//! that design, so design differences (e.g. FSB's fixed ldm=128 vs the
//! general format's ldm=width) translate mechanically into cycles.

use super::config::MemSpace;

/// Aggregate work performed by one (representative) warp.
#[derive(Clone, Debug, Default)]
pub struct WarpWork {
    /// WMMA bit-tile loads: (ldm_bits, memory space, count)
    pub tile_loads: Vec<(usize, MemSpace, usize)>,
    /// WMMA int-tile stores: (space, count)
    pub tile_stores: Vec<(MemSpace, usize)>,
    /// bulk vectorized global loads, bytes (LDG.E.128 staging)
    pub bulk_load_bytes: usize,
    /// bulk global stores, bytes (e.g. binarized output words)
    pub bulk_store_bytes: usize,
    /// bytes written into shared memory (staging traffic; consumes the
    /// SM's shared-memory bandwidth together with shared tile loads)
    pub shared_store_bytes: usize,
    /// bmma_sync ops with independent accumulators
    pub bmma_ops: usize,
    /// bmma_sync ops accumulating into the same tile C
    pub bmma_same_acc_ops: usize,
    /// INT32 lane-ops (xor/add — BSTC path), per warp across all lanes
    pub intu_ops: usize,
    /// SFU lane-ops (popc — BSTC path)
    pub sfu_ops: usize,
    /// FP16 tensor-core FMAs (HMMA baselines), per warp
    pub hmma_fmas: usize,
    /// int4 tensor-core MACs (Cutlass uint4 baseline), per warp
    pub int4_macs: usize,
    /// FP32 lane-ops on the FPU (first-layer BWN path)
    pub fp_ops: usize,
    /// __syncthreads()-class barriers
    pub cta_syncs: usize,
}

impl WarpWork {
    /// Add a WMMA tile-load group.
    pub fn load_tiles(&mut self, ldm_bits: usize, space: MemSpace, count: usize) {
        if count > 0 {
            self.tile_loads.push((ldm_bits, space, count));
        }
    }

    pub fn store_tiles(&mut self, space: MemSpace, count: usize) {
        if count > 0 {
            self.tile_stores.push((space, count));
        }
    }
}

/// One kernel launch.
#[derive(Clone, Debug)]
pub struct KernelTrace {
    pub name: String,
    /// CTAs in the grid
    pub grid_ctas: usize,
    /// warps per CTA
    pub warps_per_cta: usize,
    /// shared memory per CTA, bytes (occupancy limiter)
    pub smem_per_cta: usize,
    /// registers per thread (occupancy limiter)
    pub regs_per_thread: usize,
    /// aggregate work of one warp (all warps assumed symmetric)
    pub warp: WarpWork,
    /// number of grid-wide cooperative-group barriers inside the kernel
    pub coop_syncs: usize,
    /// kernel launches this trace represents (fused BNN = 1)
    pub launches: usize,
    /// unique data footprint, bytes (compulsory traffic).  When 0, all
    /// requested traffic is charged to DRAM; otherwise re-reads beyond
    /// the footprint are filtered through the L2 miss model.
    pub compulsory_bytes: f64,
    /// unique bytes *loaded* (operands only — excludes the streamed
    /// output).  Drives cache-spill behaviour; 0 = use compulsory_bytes.
    pub load_footprint_bytes: f64,
    /// for staged/tiled schemes: resident bytes one CTA needs at a time
    /// (its shared-memory panels).  The cache-spill footprint becomes
    /// min(load_footprint, sms * this) — swizzled rasterization keeps a
    /// wave's panels L2-resident even when the matrices don't fit.
    /// 0 = unstaged (whole rows stream through the warp).
    pub wave_bytes_per_cta: f64,
}

impl KernelTrace {
    pub fn new(name: &str) -> KernelTrace {
        KernelTrace {
            name: name.to_string(),
            grid_ctas: 1,
            warps_per_cta: 1,
            smem_per_cta: 0,
            regs_per_thread: 32,
            warp: WarpWork::default(),
            coop_syncs: 0,
            launches: 1,
            compulsory_bytes: 0.0,
            load_footprint_bytes: 0.0,
            wave_bytes_per_cta: 0.0,
        }
    }

    pub fn total_warps(&self) -> usize {
        self.grid_ctas * self.warps_per_cta
    }

    /// Total DRAM bytes moved by the whole grid (loads + stores),
    /// charging sector over-fetch for strided tile loads.
    pub fn dram_bytes(&self) -> f64 {
        let w = &self.warp;
        let mut per_warp = 0.0;
        for &(ldm, space, count) in &w.tile_loads {
            if space == MemSpace::Global {
                per_warp += (super::wmma::load_bytes_moved(ldm) * count) as f64;
            }
        }
        for &(space, count) in &w.tile_stores {
            if space == MemSpace::Global {
                per_warp += (super::wmma::store_bytes_moved() * count) as f64;
            }
        }
        per_warp += (w.bulk_load_bytes + w.bulk_store_bytes) as f64;
        per_warp * self.total_warps() as f64
    }

    /// Total bmma ops over the grid.
    pub fn total_bmma(&self) -> usize {
        (self.warp.bmma_ops + self.warp.bmma_same_acc_ops) * self.total_warps()
    }

    /// Shared-memory bytes moved per warp (loads + staging stores).
    pub fn shared_bytes_per_warp(&self) -> f64 {
        let w = &self.warp;
        let mut b = w.shared_store_bytes as f64;
        for &(_, space, count) in &w.tile_loads {
            if space == MemSpace::Shared {
                b += (128 * count) as f64;
            }
        }
        for &(space, count) in &w.tile_stores {
            if space == MemSpace::Shared {
                b += (256 * count) as f64;
            }
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_accounting() {
        let mut t = KernelTrace::new("t");
        t.grid_ctas = 2;
        t.warps_per_cta = 2;
        t.warp.load_tiles(128, MemSpace::Global, 3); // 3 x 128B
        t.warp.load_tiles(128, MemSpace::Shared, 5); // not DRAM
        t.warp.store_tiles(MemSpace::Global, 1); // 256B
        t.warp.bulk_load_bytes = 100;
        assert_eq!(t.dram_bytes(), ((3 * 128 + 256 + 100) * 4) as f64);
    }

    #[test]
    fn overfetch_charged() {
        let mut t = KernelTrace::new("t");
        t.warp.load_tiles(256, MemSpace::Global, 1); // 2x over-fetch
        assert_eq!(t.dram_bytes(), 256.0);
    }

    #[test]
    fn zero_count_loads_skipped() {
        let mut w = WarpWork::default();
        w.load_tiles(128, MemSpace::Global, 0);
        assert!(w.tile_loads.is_empty());
    }
}
