//! GPU model parameters (Table 2 of the paper + §4 calibration numbers).

/// Which memory space a WMMA tile load/store touches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemSpace {
    Global,
    Shared,
}

/// Parameters of one simulated Turing GPU.
#[derive(Clone, Debug)]
pub struct GpuModel {
    pub name: &'static str,
    pub chip: &'static str,
    // ---- Table 2 ----
    pub sms: usize,
    pub max_ctas_per_sm: usize,
    pub max_warps_per_sm: usize,
    pub max_threads_per_cta: usize,
    pub regs_per_sm: usize,
    pub shared_per_sm: usize,
    pub tcus_per_sm: usize,
    pub mem_bytes: usize,
    pub mem_bw_bytes: f64,
    // ---- clocks ----
    pub clock_hz: f64,
    // ---- §4.3 BMMA pipeline calibration ----
    /// raw (unpipelined) bmma_sync latency in cycles (~201 / ~190)
    pub bmma_raw_cycles: f64,
    /// incremental cycles per op with distinct accumulators
    pub bmma_pipe_cycles: f64,
    /// incremental cycles per op when reusing the same accumulator
    pub bmma_same_acc_cycles: f64,
    // ---- §4.1 memory calibration ----
    /// base global-memory wmma-load latency (fast-stride case)
    pub global_load_base_cycles: f64,
    /// extra cycles per additional L1 sector issue cycle
    pub sector_issue_cycles: f64,
    /// shared-memory wmma-load latency (≈ 5x less than global, §4.1)
    pub shared_load_base_cycles: f64,
    /// does shared-memory latency vary with stride (RTX2080 shows mild
    /// bank effects; 2080Ti is flat — §4.1 observation (2))
    pub shared_stride_sensitive: bool,
    /// global store latency (no stride pattern, §4.2)
    pub global_store_cycles: f64,
    pub shared_store_cycles: f64,
    // ---- issue/throughput rates ----
    /// subcores per SM (each issues 1 instr/cycle)
    pub subcores: usize,
    /// INT32 lanes per SM (BSTC xor/add path)
    pub intu_lanes: usize,
    /// SFU-issued ops per cycle per SM (BSTC popc path)
    pub sfu_rate: f64,
    /// FP16 FMA per cycle per TCU (HMMA; Volta/Turing: 64)
    pub hmma_fma_per_tcu: f64,
    /// kernel launch + teardown overhead, seconds (§6.2 cites ~20us)
    pub launch_overhead_s: f64,
    /// grid-wide cooperative-group sync cost, cycles (per layer barrier)
    pub coop_sync_cycles: f64,
    /// L2 capacity, bytes (drives the re-read-traffic miss model: once a
    /// kernel's unique working set spills L2, re-reads hit DRAM — this is
    /// the ">4K sizes drop" mechanism of §7.2 observation (I))
    pub l2_bytes: f64,
    /// L2 bandwidth as a multiple of DRAM bandwidth
    pub l2_bw_mult: f64,
    /// global scale on the stride-based L1 miss factors (0.25 =
    /// calibrated default; bench_ablation A4 sweeps it)
    pub l1_miss_rate: f64,
}

impl GpuModel {
    /// Peak DRAM bytes per cycle for the whole chip.
    pub fn bytes_per_cycle(&self) -> f64 {
        self.mem_bw_bytes / self.clock_hz
    }

    /// Seconds for a cycle count.
    pub fn secs(&self, cycles: f64) -> f64 {
        cycles / self.clock_hz
    }

    /// Peak binary TOPS via BTC (for roofline reporting): each bmma is
    /// 8*8*128 mul + acc = 2*8192 ops at 1 op / pipe_cycles / subcore.
    pub fn peak_btc_tops(&self) -> f64 {
        let ops_per_bmma = 2.0 * 8.0 * 8.0 * 128.0;
        let per_sm = ops_per_bmma / self.bmma_pipe_cycles * self.subcores as f64;
        per_sm * self.sms as f64 * self.clock_hz / 1e12
    }

    /// Peak FP16 tensor-core TFLOPS.
    pub fn peak_hmma_tflops(&self) -> f64 {
        2.0 * self.hmma_fma_per_tcu
            * (self.tcus_per_sm * self.sms) as f64
            * self.clock_hz
            / 1e12
    }
}

/// NVIDIA GeForce RTX 2080 (TU104), Table 2 row 2.
pub const RTX2080: GpuModel = GpuModel {
    name: "RTX2080",
    chip: "TU104",
    sms: 46,
    max_ctas_per_sm: 16,
    max_warps_per_sm: 32,
    max_threads_per_cta: 1024,
    regs_per_sm: 64 * 1024,
    shared_per_sm: 64 * 1024,
    tcus_per_sm: 8,
    mem_bytes: 8 * 1024 * 1024 * 1024,
    mem_bw_bytes: 448.0e9,
    clock_hz: 1.710e9,
    bmma_raw_cycles: 201.0,
    bmma_pipe_cycles: 4.0,
    bmma_same_acc_cycles: 10.0,
    global_load_base_cycles: 440.0,
    sector_issue_cycles: 24.0,
    shared_load_base_cycles: 86.0,
    shared_stride_sensitive: true,
    global_store_cycles: 360.0,
    shared_store_cycles: 48.0,
    subcores: 4,
    intu_lanes: 64,
    sfu_rate: 32.0,
    hmma_fma_per_tcu: 64.0,
    launch_overhead_s: 5.0e-6,
    coop_sync_cycles: 2600.0,
    l2_bytes: 4.0 * 1024.0 * 1024.0,
    l2_bw_mult: 4.0,
    l1_miss_rate: 0.25,
};

/// NVIDIA GeForce RTX 2080 Ti (TU102), Table 2 row 1.
pub const RTX2080TI: GpuModel = GpuModel {
    name: "RTX2080Ti",
    chip: "TU102",
    sms: 68,
    max_ctas_per_sm: 16,
    max_warps_per_sm: 32,
    max_threads_per_cta: 1024,
    regs_per_sm: 64 * 1024,
    shared_per_sm: 64 * 1024,
    tcus_per_sm: 8,
    mem_bytes: 11 * 1024 * 1024 * 1024,
    mem_bw_bytes: 616.0e9,
    clock_hz: 1.545e9,
    bmma_raw_cycles: 190.0,
    bmma_pipe_cycles: 4.0,
    bmma_same_acc_cycles: 10.0,
    global_load_base_cycles: 430.0,
    sector_issue_cycles: 22.0,
    shared_load_base_cycles: 78.0,
    shared_stride_sensitive: false,
    global_store_cycles: 350.0,
    shared_store_cycles: 44.0,
    subcores: 4,
    intu_lanes: 64,
    sfu_rate: 32.0,
    hmma_fma_per_tcu: 64.0,
    launch_overhead_s: 5.0e-6,
    coop_sync_cycles: 3000.0,
    l2_bytes: 5.5 * 1024.0 * 1024.0,
    l2_bw_mult: 4.0,
    l1_miss_rate: 0.25,
};

/// Both evaluation GPUs, in Table 2 order.
pub fn all_gpus() -> [&'static GpuModel; 2] {
    [&RTX2080TI, &RTX2080]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values() {
        assert_eq!(RTX2080TI.sms, 68);
        assert_eq!(RTX2080.sms, 46);
        assert_eq!(RTX2080TI.tcus_per_sm, 8);
        assert!((RTX2080TI.mem_bw_bytes - 616e9).abs() < 1.0);
        assert!((RTX2080.mem_bw_bytes - 448e9).abs() < 1.0);
    }

    #[test]
    fn paper_bmma_calibration() {
        // §4.3: ~201 / ~190 cycles raw; +4 pipelined; +10 same-acc.
        assert!((RTX2080.bmma_raw_cycles - 201.0).abs() < 1e-9);
        assert!((RTX2080TI.bmma_raw_cycles - 190.0).abs() < 1e-9);
        assert_eq!(RTX2080.bmma_pipe_cycles, 4.0);
        assert_eq!(RTX2080.bmma_same_acc_cycles, 10.0);
    }

    #[test]
    fn shared_is_about_5x_faster_than_global() {
        for g in all_gpus() {
            let ratio = g.global_load_base_cycles / g.shared_load_base_cycles;
            assert!(ratio > 4.0 && ratio < 7.0, "{}: ratio {ratio}", g.name);
        }
    }

    #[test]
    fn peak_rates_sane() {
        // BTC peak should be far above FP16 peak (the 16x theory claim,
        // modulated by pipeline rates).
        for g in all_gpus() {
            assert!(g.peak_btc_tops() > 2.0 * g.peak_hmma_tflops());
        }
        // 2080Ti FP16 TC peak ~ 107 TFLOPS at boost; at base clock less.
        let t = RTX2080TI.peak_hmma_tflops();
        assert!(t > 80.0 && t < 130.0, "hmma peak {t}");
    }
}
