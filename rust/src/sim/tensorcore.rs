//! Tensor-core pipeline model (§4.3, Figs 10–13) + baseline datapaths.

use super::config::GpuModel;

/// Total latency (cycles) of `n` back-to-back bmma_sync ops in one warp.
///
/// §4.3: raw latency ~201/190 cycles; each additional op adds 4 cycles
/// when the accumulators are independent (pure pipelining) and 10 cycles
/// when every op accumulates into the same tile C (a 6-cycle
/// read-after-write stall on the accumulator).
pub fn bmma_latency(gpu: &GpuModel, n_ops: usize, same_acc: bool) -> f64 {
    if n_ops == 0 {
        return 0.0;
    }
    let inc = if same_acc { gpu.bmma_same_acc_cycles } else { gpu.bmma_pipe_cycles };
    gpu.bmma_raw_cycles + (n_ops as f64 - 1.0) * inc
}

/// Warp-level parallelism needed to hide the raw latency: with each
/// subcore issuing one bmma per pipe interval, a warp must wait
/// raw/pipe issues — §4.3's WLP/ILP saturation estimate.
pub fn warps_to_saturate(gpu: &GpuModel, same_acc: bool) -> f64 {
    let inc = if same_acc { gpu.bmma_same_acc_cycles } else { gpu.bmma_pipe_cycles };
    gpu.bmma_raw_cycles / inc
}

/// Steady-state bmma ops per cycle for one SM (4 subcores, each issuing
/// one bmma per pipe interval once saturated).
pub fn bmma_rate_per_sm(gpu: &GpuModel, same_acc: bool) -> f64 {
    let inc = if same_acc { gpu.bmma_same_acc_cycles } else { gpu.bmma_pipe_cycles };
    gpu.subcores as f64 / inc
}

/// Steady-state FP16 HMMA FMA/cycle for one SM (all TCUs).
pub fn hmma_fma_rate_per_sm(gpu: &GpuModel) -> f64 {
    gpu.hmma_fma_per_tcu * gpu.tcus_per_sm as f64
}

/// int4 tensor-core MAC/cycle for one SM: Turing int4 mode runs at 4x
/// the FP16 FMA rate (but 4x the bandwidth per element vs b1).
pub fn int4_mac_rate_per_sm(gpu: &GpuModel) -> f64 {
    4.0 * hmma_fma_rate_per_sm(gpu)
}

/// INT32 logic ops (xor/add) per cycle per SM — BSTC's INTU path.
pub fn intu_rate_per_sm(gpu: &GpuModel) -> f64 {
    gpu.intu_lanes as f64
}

/// popc ops per cycle per SM — BSTC's SFU path (§2: "INTUs and SFUs").
pub fn sfu_rate_per_sm(gpu: &GpuModel) -> f64 {
    gpu.sfu_rate
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{RTX2080, RTX2080TI};

    #[test]
    fn fig10_13_pipeline_increments() {
        // one more op costs +4 (different acc) / +10 (same acc)
        for gpu in [&RTX2080, &RTX2080TI] {
            let d = bmma_latency(gpu, 11, false) - bmma_latency(gpu, 10, false);
            assert_eq!(d, 4.0);
            let s = bmma_latency(gpu, 11, true) - bmma_latency(gpu, 10, true);
            assert_eq!(s, 10.0);
        }
    }

    #[test]
    fn raw_latency_matches_paper() {
        assert_eq!(bmma_latency(&RTX2080, 1, false), 201.0);
        assert_eq!(bmma_latency(&RTX2080TI, 1, false), 190.0);
        assert_eq!(bmma_latency(&RTX2080, 0, false), 0.0);
    }

    #[test]
    fn saturation_wlp_is_reachable() {
        // §4.3 argues 32 warps/SM suffice to saturate: raw/pipe ≈ 50
        // issue slots across 4 subcores ≈ 12.6 warps/subcore < 32.
        let w = warps_to_saturate(&RTX2080TI, false);
        assert!(w / RTX2080TI.subcores as f64 <= RTX2080TI.max_warps_per_sm as f64 / 2.0);
    }

    #[test]
    fn same_acc_reduces_rate() {
        assert!(
            bmma_rate_per_sm(&RTX2080, true) < bmma_rate_per_sm(&RTX2080, false)
        );
    }
}
