//! Turing GPU timing model ("the testbed substitute").
//!
//! The paper's evaluation ran on physical RTX 2080 / 2080 Ti GPUs; this
//! environment has none, so — per the reproduction substitution rule —
//! the microarchitectural mechanisms the paper documents in §4 are
//! implemented as an analytic cycle model:
//!
//! * `memory` — warp-level address generation, 32-byte sector coalescing
//!   and the dual-port L1 sector interleave that makes `ldm = 128+256k`
//!   the fast strides (§4.1's explanation, implemented literally);
//! * `wmma`  — `load/store_matrix_sync` latency as a function of `ldm`
//!   and memory space (Figs 2–9);
//! * `tensorcore` — the BMMA pipeline: ~200-cycle raw latency, 4-cycle
//!   pipelined issue, +6 cycles when accumulating into the same tile C
//!   (Figs 10–13), plus FP16 HMMA and int4 rates for the baselines;
//! * `trace` — the per-kernel event summary each kernel implementation
//!   emits (loads with their strides, bmma ops, INTU/SFU work, stores);
//! * `engine` — occupancy + roofline composition turning a trace into
//!   cycles and seconds on a given `GpuModel`.
//!
//! Calibration targets are the paper's own §4 numbers; everything in
//! Figs 16–28 is then *predicted* by the model, not fitted.

pub mod config;
pub mod engine;
pub mod memory;
pub mod tensorcore;
pub mod trace;
pub mod wmma;

pub use config::{GpuModel, MemSpace, RTX2080, RTX2080TI};
pub use engine::{CostBreakdown, Engine};
pub use trace::{KernelTrace, WarpWork};
