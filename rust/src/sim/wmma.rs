//! WMMA load/store latency model (§4.1–4.2, Figs 2–9).

use super::config::GpuModel;
pub use super::config::MemSpace;
use super::memory;

/// Average per-warp latency (cycles) of `load_matrix_sync` for a b1
/// bit-tile with row stride `ldm_bits`, from the given memory space.
///
/// Global memory: base latency + extra L1 sector-issue cycles from the
/// coalescing/port model (this is what produces the Figs 2/4 shape with
/// minima at ldm = 128 and 128+256k).
/// Shared memory: flat ~5x-lower latency on the 2080Ti; the 2080 shows a
/// mild bank-conflict ripple on 32B-aligned strides (Figs 3 vs 5).
pub fn load_latency(gpu: &GpuModel, ldm_bits: usize, space: MemSpace) -> f64 {
    let info = memory::bit_tile_coalesce(0, ldm_bits);
    match space {
        MemSpace::Global => {
            // the minimum achievable issue is 2 cycles (4 sectors, 2 ports)
            let extra = (info.issue_cycles as f64 - 2.0).max(0.0);
            gpu.global_load_base_cycles + extra * gpu.sector_issue_cycles
        }
        MemSpace::Shared => {
            if gpu.shared_stride_sensitive {
                let extra = (info.issue_cycles as f64 - 2.0).max(0.0);
                gpu.shared_load_base_cycles + extra * (gpu.sector_issue_cycles * 0.12)
            } else {
                gpu.shared_load_base_cycles
            }
        }
    }
}

/// Bytes actually moved from DRAM by one bit-tile load (over-fetch with
/// bad strides is charged at full sector granularity).
pub fn load_bytes_moved(ldm_bits: usize) -> usize {
    memory::bit_tile_coalesce(0, ldm_bits).bytes_moved
}

/// `store_matrix_sync` of the 8x8 i32 tile: §4.2 found no stride
/// pattern — modeled as a flat cost per space.
pub fn store_latency(gpu: &GpuModel, _ldm_elems: usize, space: MemSpace) -> f64 {
    match space {
        MemSpace::Global => gpu.global_store_cycles,
        MemSpace::Shared => gpu.shared_store_cycles,
    }
}

/// Bytes moved by one int-tile store (8x8 x 4B).
pub fn store_bytes_moved() -> usize {
    256
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{all_gpus, RTX2080, RTX2080TI};

    #[test]
    fn fig2_shape_minima_at_128_and_384() {
        // paper Fig 2/4: ldm=128 and 384 are the global-memory minima
        for gpu in all_gpus() {
            let l128 = load_latency(gpu, 128, MemSpace::Global);
            let l256 = load_latency(gpu, 256, MemSpace::Global);
            let l384 = load_latency(gpu, 384, MemSpace::Global);
            let l512 = load_latency(gpu, 512, MemSpace::Global);
            assert!(l128 < l256, "{}: 128 beats 256", gpu.name);
            assert!(l128 <= l384, "{}: 128 fastest", gpu.name);
            assert!(l384 < l256, "{}: 384 beats 256", gpu.name);
            assert!(l384 < l512, "{}: 384 beats 512", gpu.name);
        }
    }

    #[test]
    fn fast_family_is_flat() {
        for gpu in all_gpus() {
            let l384 = load_latency(gpu, 384, MemSpace::Global);
            for ldm in [640, 896] {
                assert_eq!(load_latency(gpu, ldm, MemSpace::Global), l384);
            }
        }
    }

    #[test]
    fn shared_5x_faster_and_flat_on_ti() {
        // §4.1 observations (1) and (2)
        let g = load_latency(&RTX2080TI, 1024, MemSpace::Global);
        let s = load_latency(&RTX2080TI, 1024, MemSpace::Shared);
        assert!(g / s > 5.0, "global/shared = {}", g / s);
        let s2 = load_latency(&RTX2080TI, 256, MemSpace::Shared);
        assert_eq!(s, s2, "2080Ti shared is stride-insensitive");
        // 2080 shared latency is higher than Ti and mildly stride-varying
        assert!(
            load_latency(&RTX2080, 256, MemSpace::Shared)
                > load_latency(&RTX2080, 128, MemSpace::Shared)
        );
        assert!(
            load_latency(&RTX2080, 128, MemSpace::Shared)
                > load_latency(&RTX2080TI, 128, MemSpace::Shared)
        );
    }

    #[test]
    fn store_has_no_stride_pattern() {
        for gpu in all_gpus() {
            let a = store_latency(gpu, 8, MemSpace::Global);
            let b = store_latency(gpu, 1024, MemSpace::Global);
            assert_eq!(a, b);
            assert!(store_latency(gpu, 8, MemSpace::Shared) < a);
        }
    }

    #[test]
    fn bad_strides_overfetch() {
        assert_eq!(load_bytes_moved(128), 128);
        assert_eq!(load_bytes_moved(256), 256); // 2x over-fetch
        assert_eq!(load_bytes_moved(1024), 256);
    }
}
