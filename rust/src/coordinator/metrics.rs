//! Serving metrics: bounded latency histogram + throughput counters
//! + the obs snapshot the report/JSON/Prometheus renderings share.

use std::sync::Mutex;
use std::time::Instant;

use crate::obs::export::{LayerAttr, RepackEdge, Snapshot};
use crate::obs::hist::LogHistogram;
use crate::obs::trace::TraceRing;
use crate::obs::window::{WindowStats, Windows};
use crate::util::stats::Summary;

/// Batch traces retained for inspection (ring capacity; older traces
/// are evicted and counted, never accumulated).
const TRACE_CAPACITY: usize = 256;

/// Thread-safe metrics sink.  Memory is bounded regardless of request
/// count: latencies land in a fixed-footprint [`LogHistogram`], traces
/// in a fixed-capacity [`TraceRing`], and everything else is counters.
pub struct Metrics {
    inner: Mutex<Inner>,
    hist: LogHistogram,
    traces: TraceRing,
    windows: Windows,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            inner: Mutex::new(Inner::default()),
            hist: LogHistogram::new(),
            traces: TraceRing::new(TRACE_CAPACITY),
            windows: Windows::new(),
        }
    }
}

#[derive(Default)]
struct Inner {
    completed: u64,
    batches: u64,
    padded_rows: u64,
    real_rows: u64,
    /// largest padded batch executed — the observable the SLO batch
    /// sizer moves (an SLO-restricted model never reaches the largest
    /// fixed bucket; see `serve::slo`)
    max_batch_rows: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
    /// rows executed by an engine-backed model (padding included)
    engine_rows: u64,
    /// wall time the engine spent inside `run_batch`
    engine_busy_s: f64,
    /// latest plan-cache counter snapshot from the serving model's
    /// builder (cumulative over the cache, not per model)
    plan_cache_hits: u64,
    plan_cache_misses: u64,
    /// live-feedback re-plans the served engine model performed
    replans: u64,
    /// latest per-scheme measured/predicted EWMA cost ratios from the
    /// tuner's live feedback loop (scheme name, ratio, samples)
    cost_drift: Vec<(String, f64, u64)>,
    /// latest cumulative explicit layout-repack counters from the
    /// serving executor: (consuming scheme name, ops, streamed bytes)
    repacks: Vec<(String, u64, u64)>,
    /// latest cumulative per-layer attribution from the serving
    /// executor (calls, measured secs, predicted secs per plan layer)
    layers: Vec<LayerAttr>,
    /// latest cumulative per-edge repack attribution from the serving
    /// executor
    repack_edges: Vec<RepackEdge>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&self, real_rows: usize, padded_rows: usize, latencies_s: &[f64]) {
        for &lat in latencies_s {
            self.hist.record(lat);
        }
        self.windows.record_requests(latencies_s);
        let mut m = self.inner.lock().unwrap();
        if m.started.is_none() {
            m.started = Some(Instant::now());
        }
        m.finished = Some(Instant::now());
        m.batches += 1;
        m.real_rows += real_rows as u64;
        m.padded_rows += padded_rows as u64;
        m.max_batch_rows = m.max_batch_rows.max(padded_rows as u64);
        m.completed += latencies_s.len() as u64;
    }

    /// Largest padded batch executed so far (0 before the first batch).
    pub fn max_batch_rows(&self) -> u64 {
        self.inner.lock().unwrap().max_batch_rows
    }

    /// Record one engine batch execution: `rows` images in `secs` of
    /// model wall time.  This is the engine's images/sec feed — it
    /// measures executor throughput (busy time), while `throughput_fps`
    /// measures end-to-end request throughput (incl. queueing).
    pub fn record_engine_batch(&self, rows: usize, secs: f64) {
        let mut m = self.inner.lock().unwrap();
        m.engine_rows += rows as u64;
        m.engine_busy_s += secs;
    }

    /// Engine executor throughput: images per busy-second.
    pub fn engine_images_per_sec(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.engine_busy_s > 0.0 {
            m.engine_rows as f64 / m.engine_busy_s
        } else {
            0.0
        }
    }

    pub fn engine_rows(&self) -> u64 {
        self.inner.lock().unwrap().engine_rows
    }

    /// Record the serving plan cache's cumulative hit/miss counters
    /// (latest snapshot wins — the counters live on the `PlanCache`,
    /// this surfaces them next to the serving metrics).
    pub fn record_plan_cache(&self, hits: u64, misses: u64) {
        let mut m = self.inner.lock().unwrap();
        m.plan_cache_hits = hits;
        m.plan_cache_misses = misses;
    }

    pub fn plan_cache_hits(&self) -> u64 {
        self.inner.lock().unwrap().plan_cache_hits
    }

    pub fn plan_cache_misses(&self) -> u64 {
        self.inner.lock().unwrap().plan_cache_misses
    }

    /// Count one live-feedback re-plan of the served engine model.
    pub fn record_replan(&self) {
        self.inner.lock().unwrap().replans += 1;
    }

    pub fn replans(&self) -> u64 {
        self.inner.lock().unwrap().replans
    }

    /// Publish the latest per-scheme measured/predicted cost ratios
    /// from the tuner's live feedback sink.
    pub fn set_cost_drift(&self, drift: Vec<(String, f64, u64)>) {
        self.inner.lock().unwrap().cost_drift = drift;
    }

    /// `(scheme name, EWMA measured/predicted ratio, samples)` per
    /// scheme with live data.
    pub fn cost_drift(&self) -> Vec<(String, f64, u64)> {
        self.inner.lock().unwrap().cost_drift.clone()
    }

    /// Publish the serving executor's cumulative explicit layout-repack
    /// counters (latest snapshot wins — the counters live on the
    /// executor, this surfaces them next to the serving metrics).
    pub fn set_repacks(&self, repacks: Vec<(String, u64, u64)>) {
        self.inner.lock().unwrap().repacks = repacks;
    }

    /// `(consuming scheme name, explicit repack ops, streamed bytes)`
    /// per scheme the executor has converted activations for.
    pub fn repack_stats(&self) -> Vec<(String, u64, u64)> {
        self.inner.lock().unwrap().repacks.clone()
    }

    /// Publish the serving executor's cumulative per-layer attribution
    /// (latest snapshot wins — the counters accumulate on the executor).
    pub fn set_layer_attribution(&self, layers: Vec<LayerAttr>) {
        self.inner.lock().unwrap().layers = layers;
    }

    /// Per-plan-layer cumulative (calls, measured secs, predicted
    /// secs) — the per-layer drift feed.
    pub fn layer_attribution(&self) -> Vec<LayerAttr> {
        self.inner.lock().unwrap().layers.clone()
    }

    /// Publish the serving executor's cumulative per-edge repack
    /// attribution (latest snapshot wins).
    pub fn set_repack_edges(&self, edges: Vec<RepackEdge>) {
        self.inner.lock().unwrap().repack_edges = edges;
    }

    /// Explicit repack traffic per plan edge (layer, src→dst layouts).
    pub fn repack_edges(&self) -> Vec<RepackEdge> {
        self.inner.lock().unwrap().repack_edges.clone()
    }

    /// The batch-trace ring (push from the serving loop, inspect from
    /// tests/tools).
    pub fn traces(&self) -> &TraceRing {
        &self.traces
    }

    /// Count one admission shed in the rolling windows.  The cumulative
    /// shed counter is owned by `serve::Fleet` (which grafts it onto
    /// the snapshot); this feeds the 10s/60s shed-rate families.
    pub fn record_shed(&self) {
        self.windows.record_shed();
    }

    /// Count one SLO verdict (hit/miss) in the rolling windows.  Like
    /// sheds, the cumulative counters stay on `serve::Fleet`.
    pub fn record_slo(&self, hit: bool) {
        self.windows.record_slo(hit);
    }

    /// Rolling-window stats over the standard report windows (10s/60s).
    pub fn window_stats(&self) -> Vec<WindowStats> {
        self.windows.stats_all()
    }

    /// Latency summary from the bounded histogram — same `Summary`
    /// shape the old Vec-backed implementation returned.  n, mean,
    /// min, max are exact; percentiles are bucket-interpolated (~9%).
    pub fn latency_summary(&self) -> Summary {
        self.hist.summary()
    }

    /// The latency store's memory footprint — constant by construction
    /// (see `obs::hist`), whatever the request count.
    pub fn hist_footprint_bytes(&self) -> usize {
        self.hist.footprint_bytes()
    }

    /// Completed requests / wall time between first and last batch.
    pub fn throughput_fps(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        match (m.started, m.finished) {
            (Some(s), Some(f)) if f > s => {
                m.completed as f64 / (f - s).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    /// Fraction of executed rows that were padding (batcher efficiency).
    pub fn padding_overhead(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.padded_rows == 0 {
            0.0
        } else {
            1.0 - m.real_rows as f64 / m.padded_rows as f64
        }
    }

    pub fn completed(&self) -> u64 {
        self.inner.lock().unwrap().completed
    }

    pub fn batches(&self) -> u64 {
        self.inner.lock().unwrap().batches
    }

    /// Materialize everything into an [`obs::export::Snapshot`] — the
    /// single struct the human report, the JSON document, and the
    /// Prometheus exposition all render from.
    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        // derived quantities computed inline (the accessor methods
        // would re-take the non-reentrant lock)
        let throughput_rps = match (m.started, m.finished) {
            (Some(s), Some(f)) if f > s => {
                m.completed as f64 / (f - s).as_secs_f64()
            }
            _ => 0.0,
        };
        let padding_frac = if m.padded_rows == 0 {
            0.0
        } else {
            1.0 - m.real_rows as f64 / m.padded_rows as f64
        };
        Snapshot {
            requests: m.completed,
            batches: m.batches,
            throughput_rps,
            padding_frac,
            max_batch_rows: m.max_batch_rows,
            latency: self.hist.summary(),
            latency_buckets: self.hist.nonzero_buckets(),
            engine_rows: m.engine_rows,
            engine_busy_s: m.engine_busy_s,
            plan_cache_hits: m.plan_cache_hits,
            plan_cache_misses: m.plan_cache_misses,
            replans: m.replans,
            cost_drift: m.cost_drift.clone(),
            repacks_by_scheme: m.repacks.clone(),
            repack_edges: m.repack_edges.clone(),
            layers: m.layers.clone(),
            traces_pushed: self.traces.pushed(),
            traces_dropped: self.traces.dropped(),
            traces_capacity: self.traces.capacity() as u64,
            // fleet-level counters (sheds, steals, SLO hit-rate,
            // per-shard attribution) are owned by `serve::Fleet`, which
            // grafts them onto this snapshot in `Fleet::snapshot`
            sheds: 0,
            priority_sheds: 0,
            steals: 0,
            slo_hits: 0,
            slo_misses: 0,
            shards: Vec::new(),
            windows: self.windows.stats_all(),
            // shard health is produced by `serve::health::Watchdog`,
            // grafted by `Fleet::snapshot` alongside the fleet counters
            health: Vec::new(),
        }
    }

    /// The human one-liner — one rendering of [`Metrics::snapshot`].
    pub fn report(&self) -> String {
        self.snapshot().render_report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.record_batch(8, 8, &[0.001; 8]);
        m.record_batch(3, 8, &[0.002; 3]);
        assert_eq!(m.completed(), 11);
        assert_eq!(m.batches(), 2);
        assert_eq!(m.max_batch_rows(), 8);
        let s = m.latency_summary();
        // histogram percentiles: exact to within bucket resolution
        assert!((s.p50 - 0.001).abs() <= 0.001 * 0.1, "p50 {}", s.p50);
        assert!((s.p99 - 0.002).abs() <= 0.002 * 0.1, "p99 {}", s.p99);
        let pad = m.padding_overhead();
        assert!((pad - (1.0 - 11.0 / 16.0)).abs() < 1e-9);
        assert!(m.report().contains("requests=11"));
        m.record_batch(32, 32, &[0.001; 32]);
        assert_eq!(m.max_batch_rows(), 32, "max tracks the largest padded batch");
    }

    #[test]
    fn empty_is_sane() {
        let m = Metrics::new();
        assert_eq!(m.throughput_fps(), 0.0);
        assert_eq!(m.padding_overhead(), 0.0);
        assert_eq!(m.engine_images_per_sec(), 0.0);
    }

    #[test]
    fn plan_cache_counters_surface_in_the_report() {
        let m = Metrics::new();
        assert_eq!((m.plan_cache_hits(), m.plan_cache_misses()), (0, 0));
        assert!(!m.report().contains("plan_cache="));
        m.record_plan_cache(3, 5);
        assert_eq!((m.plan_cache_hits(), m.plan_cache_misses()), (3, 5));
        assert!(m.report().contains("plan_cache=3h/5m"), "{}", m.report());
        // latest snapshot wins (the counters are cumulative on the cache)
        m.record_plan_cache(10, 6);
        assert_eq!((m.plan_cache_hits(), m.plan_cache_misses()), (10, 6));
    }

    #[test]
    fn replans_and_drift_surface_in_the_report() {
        let m = Metrics::new();
        assert_eq!(m.replans(), 0);
        assert!(!m.report().contains("replans="));
        m.record_replan();
        m.record_replan();
        assert_eq!(m.replans(), 2);
        assert!(m.report().contains("replans=2"));
        m.set_cost_drift(vec![
            ("FASTPATH".to_string(), 1.1, 12),
            ("SBNN-64".to_string(), 0.2, 4), // 5x off, worst
        ]);
        assert_eq!(m.cost_drift().len(), 2);
        assert!(m.report().contains("drift[SBNN-64]=0.20x"), "{}", m.report());
    }

    #[test]
    fn repack_counters_surface_in_the_report() {
        let m = Metrics::new();
        assert!(m.repack_stats().is_empty());
        assert!(!m.report().contains("repack="));
        m.set_repacks(vec![
            ("FASTPATH".to_string(), 3, 12288),
            ("SBNN-64".to_string(), 1, 4096),
        ]);
        assert_eq!(m.repack_stats().len(), 2);
        // shown next to the plan-cache counters, totalled
        m.record_plan_cache(2, 1);
        let report = m.report();
        assert!(report.contains("plan_cache=2h/1m"), "{report}");
        assert!(report.contains("repack=4ops/16384B"), "{report}");
        // latest snapshot wins (counters are cumulative on the executor)
        m.set_repacks(vec![("FASTPATH".to_string(), 5, 20480)]);
        assert_eq!(m.repack_stats(), vec![("FASTPATH".to_string(), 5, 20480)]);
    }

    #[test]
    fn snapshot_carries_rolling_window_stats() {
        let m = Metrics::new();
        m.record_batch(4, 4, &[1e-3; 4]);
        m.record_shed();
        m.record_slo(true);
        m.record_slo(false);
        let snap = m.snapshot();
        assert_eq!(snap.windows.len(), 2, "one entry per report window");
        let w10 = &snap.windows[0];
        assert_eq!(w10.label(), "10s");
        assert_eq!(w10.requests, 4);
        assert_eq!(w10.sheds, 1);
        assert_eq!((w10.slo_hits, w10.slo_misses), (1, 1));
        assert!(w10.rps > 0.0, "fresh traffic has a nonzero windowed rate");
        assert!((w10.p99_s - 1e-3).abs() <= 1e-3 * 0.1, "p99 {}", w10.p99_s);
        // cumulative fleet counters stay zero here: Fleet grafts them
        assert_eq!(snap.sheds, 0);
        assert!(snap.health.is_empty());
    }

    #[test]
    fn engine_throughput_tracks_busy_time() {
        let m = Metrics::new();
        m.record_engine_batch(32, 0.004);
        m.record_engine_batch(8, 0.001);
        assert_eq!(m.engine_rows(), 40);
        let fps = m.engine_images_per_sec();
        assert!((fps - 40.0 / 0.005).abs() < 1e-6, "fps {fps}");
        assert!(m.report().contains("engine="));
    }

    #[test]
    fn snapshot_carries_attribution_and_report_matches() {
        let m = Metrics::new();
        m.record_batch(4, 4, &[0.001; 4]);
        m.record_engine_batch(4, 0.002);
        m.set_layer_attribution(vec![LayerAttr {
            index: 0,
            tag: "1024FC".to_string(),
            scheme: "FASTPATH".to_string(),
            calls: 1,
            secs: 0.002,
            predicted_s: 0.001,
        }]);
        m.set_repack_edges(vec![RepackEdge {
            layer: 1,
            src: "Row32".to_string(),
            dst: "Blocked64".to_string(),
            ops: 1,
            bytes: 512,
            secs: 1e-6,
        }]);
        let snap = m.snapshot();
        assert_eq!(snap.requests, 4);
        assert_eq!(snap.layers.len(), 1);
        assert_eq!(snap.repack_edges[0].bytes, 512);
        // report() is exactly the snapshot's rendering
        assert_eq!(m.report(), m.snapshot().render_report());
        assert!(m.report().contains("layer_drift[1024FC]=2.00x"), "{}", m.report());
    }
}
