//! Serving metrics: latency histogram + throughput counters.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Summary;

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    latencies: Vec<f64>,
    completed: u64,
    batches: u64,
    padded_rows: u64,
    real_rows: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
    /// rows executed by an engine-backed model (padding included)
    engine_rows: u64,
    /// wall time the engine spent inside `run_batch`
    engine_busy_s: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&self, real_rows: usize, padded_rows: usize, latencies_s: &[f64]) {
        let mut m = self.inner.lock().unwrap();
        if m.started.is_none() {
            m.started = Some(Instant::now());
        }
        m.finished = Some(Instant::now());
        m.batches += 1;
        m.real_rows += real_rows as u64;
        m.padded_rows += padded_rows as u64;
        m.completed += latencies_s.len() as u64;
        m.latencies.extend_from_slice(latencies_s);
    }

    /// Record one engine batch execution: `rows` images in `secs` of
    /// model wall time.  This is the engine's images/sec feed — it
    /// measures executor throughput (busy time), while `throughput_fps`
    /// measures end-to-end request throughput (incl. queueing).
    pub fn record_engine_batch(&self, rows: usize, secs: f64) {
        let mut m = self.inner.lock().unwrap();
        m.engine_rows += rows as u64;
        m.engine_busy_s += secs;
    }

    /// Engine executor throughput: images per busy-second.
    pub fn engine_images_per_sec(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.engine_busy_s > 0.0 {
            m.engine_rows as f64 / m.engine_busy_s
        } else {
            0.0
        }
    }

    pub fn engine_rows(&self) -> u64 {
        self.inner.lock().unwrap().engine_rows
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::from(&self.inner.lock().unwrap().latencies)
    }

    /// Completed requests / wall time between first and last batch.
    pub fn throughput_fps(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        match (m.started, m.finished) {
            (Some(s), Some(f)) if f > s => {
                m.completed as f64 / (f - s).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    /// Fraction of executed rows that were padding (batcher efficiency).
    pub fn padding_overhead(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.padded_rows == 0 {
            0.0
        } else {
            1.0 - m.real_rows as f64 / m.padded_rows as f64
        }
    }

    pub fn completed(&self) -> u64 {
        self.inner.lock().unwrap().completed
    }

    pub fn batches(&self) -> u64 {
        self.inner.lock().unwrap().batches
    }

    pub fn report(&self) -> String {
        let s = self.latency_summary();
        let mut out = format!(
            "requests={} batches={} p50={:.3}ms p90={:.3}ms p99={:.3}ms \
             mean={:.3}ms throughput={:.0} req/s padding={:.1}%",
            self.completed(),
            self.batches(),
            s.p50 * 1e3,
            s.p90 * 1e3,
            s.p99 * 1e3,
            s.mean * 1e3,
            self.throughput_fps(),
            self.padding_overhead() * 100.0
        );
        if self.engine_rows() > 0 {
            out.push_str(&format!(
                " engine={:.0} img/s",
                self.engine_images_per_sec()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.record_batch(8, 8, &[0.001; 8]);
        m.record_batch(3, 8, &[0.002; 3]);
        assert_eq!(m.completed(), 11);
        assert_eq!(m.batches(), 2);
        let s = m.latency_summary();
        assert!(s.p50 >= 0.001 && s.p50 <= 0.002);
        let pad = m.padding_overhead();
        assert!((pad - (1.0 - 11.0 / 16.0)).abs() < 1e-9);
        assert!(m.report().contains("requests=11"));
    }

    #[test]
    fn empty_is_sane() {
        let m = Metrics::new();
        assert_eq!(m.throughput_fps(), 0.0);
        assert_eq!(m.padding_overhead(), 0.0);
        assert_eq!(m.engine_images_per_sec(), 0.0);
    }

    #[test]
    fn engine_throughput_tracks_busy_time() {
        let m = Metrics::new();
        m.record_engine_batch(32, 0.004);
        m.record_engine_batch(8, 0.001);
        assert_eq!(m.engine_rows(), 40);
        let fps = m.engine_images_per_sec();
        assert!((fps - 40.0 / 0.005).abs() < 1e-6, "fps {fps}");
        assert!(m.report().contains("engine="));
    }
}
