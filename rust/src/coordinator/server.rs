//! The inference server: router + batcher + worker loop.
//!
//! The worker thread owns the PJRT engine (the xla client is not Send +
//! Sync, so it is constructed inside the worker — matching the paper's
//! one-process-per-GPU topology).  Clients submit requests through a
//! channel and receive responses on per-request channels.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{Batcher, BatcherConfig, Request};
use super::metrics::Metrics;
use crate::obs::export::Snapshot;
use crate::obs::trace::{BatchTrace, Span};

/// A batch-executing model.  Implementations: the PJRT MLP (serve_mnist)
/// and the in-process mock used by coordinator tests.
pub trait BatchModel {
    /// Execute `padded` rows of `row_elems` floats; return logits
    /// (padded x out_elems, row-major).
    fn run_batch(&mut self, data: &[f32], padded: usize) -> Result<Vec<f32>>;
    fn row_elems(&self) -> usize;
    fn out_elems(&self) -> usize;
    /// Batch sizes this model was compiled for.
    fn buckets(&self) -> Vec<usize>;
    /// Per-layer (and repack) spans for the most recent `run_batch`,
    /// for the batch's `obs::trace`.  Default: none (opaque models).
    fn layer_spans(&self) -> Vec<Span> {
        Vec::new()
    }
    /// The model's own engine-side telemetry snapshot (per-layer
    /// attribution, plan-cache counters, drift), grafted into the
    /// server snapshot at `obs_dump` time.  Default: none.
    fn obs_snapshot(&self) -> Option<Snapshot> {
        None
    }
    /// Cumulative live-feedback re-plans this model has performed.
    /// `serve::Fleet` workers watch this counter and re-derive their
    /// SLO-admissible batch sizes when it moves (a re-plan changes the
    /// cost model the `BatchSizer` predicted from).  Default: never.
    fn replans(&self) -> u64 {
        0
    }
}

/// One response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub argmax: usize,
    pub latency: Duration,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub max_wait: Duration,
    pub queue_capacity: usize,
    /// When set, the worker writes the final telemetry snapshot to
    /// `<stem>.json` (engine::json document) and `<stem>.prom`
    /// (Prometheus text) on shutdown.
    pub obs_dump: Option<std::path::PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(2),
            queue_capacity: 8192,
            obs_dump: None,
        }
    }
}

enum Msg {
    Infer(Request, Sender<Response>),
    Shutdown,
}

/// Handle used by clients to talk to a running server.
pub struct InferenceServer {
    tx: Sender<Msg>,
    pub metrics: Arc<Metrics>,
    worker: Option<JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl InferenceServer {
    /// Start the worker.  `factory` builds the model inside the worker
    /// thread (PJRT clients are not Send).
    pub fn start<F>(cfg: ServerConfig, factory: F) -> InferenceServer
    where
        F: FnOnce() -> Result<Box<dyn BatchModel>> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let m2 = Arc::clone(&metrics);
        let worker = std::thread::Builder::new()
            .name("tcbnn-server".into())
            .spawn(move || worker_loop(cfg, factory, rx, m2))
            .expect("spawn server worker");
        InferenceServer {
            tx,
            metrics,
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Submit one request; `None` when the worker is gone (shut down,
    /// or its factory failed), so callers can surface a typed error
    /// (`router::RouteError::Shutdown`) instead of a channel that
    /// silently never fires.
    pub fn try_submit(&self, input: Vec<f32>) -> Option<Receiver<Response>> {
        let (rtx, rrx) = channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req = Request { id, input, enqueued: Instant::now() };
        match self.tx.send(Msg::Infer(req, rtx)) {
            Ok(()) => Some(rrx),
            Err(_) => None,
        }
    }

    /// Submit one request; returns the channel the response arrives on.
    /// When the worker is gone the channel is already closed (the old
    /// behavior); use [`InferenceServer::try_submit`] to detect that
    /// case explicitly.
    pub fn submit(&self, input: Vec<f32>) -> Receiver<Response> {
        self.try_submit(input).unwrap_or_else(|| channel().1)
    }

    /// Submit many inputs and wait for all responses (closed loop).
    pub fn submit_all(&self, inputs: Vec<Vec<f32>>) -> Vec<Response> {
        let receivers: Vec<Receiver<Response>> =
            inputs.into_iter().map(|x| self.submit(x)).collect();
        receivers
            .into_iter()
            .map(|r| r.recv().expect("server alive"))
            .collect()
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop<F>(
    cfg: ServerConfig,
    factory: F,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
) where
    F: FnOnce() -> Result<Box<dyn BatchModel>>,
{
    // a failed factory ends the worker cleanly: the request channel
    // closes, so submits surface as `try_submit() == None` (typed
    // `RouteError::Shutdown` at the router) instead of a panic
    let mut model = match factory() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("tcbnn-server: model factory failed, worker exiting: {e:#}");
            return;
        }
    };
    let bcfg = BatcherConfig {
        buckets: model.buckets(),
        max_wait: cfg.max_wait,
        row_elems: model.row_elems(),
        capacity: cfg.queue_capacity,
    };
    let mut batcher = Batcher::new(bcfg);
    let mut waiters: std::collections::HashMap<u64, Sender<Response>> =
        std::collections::HashMap::new();
    let mut enqueue_times: std::collections::HashMap<u64, Instant> =
        std::collections::HashMap::new();
    let mut shutting_down = false;

    loop {
        // 1. drain the channel.  Three modes:
        //    * a batch is ready (or we're shutting down): non-blocking
        //      drain, bounded so a sustained flood cannot starve batch
        //      formation;
        //    * partial batch pending: sleep until its flush deadline
        //      (no busy-spin), waking early on new arrivals;
        //    * idle: block briefly.
        let mut drained = 0usize;
        loop {
            let batch_due = shutting_down || batcher.ready(Instant::now());
            if batch_due && drained >= 4096 {
                break; // bounded drain: go run the ready batch
            }
            let msg = if batch_due {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(_) => {
                        shutting_down = true;
                        break;
                    }
                }
            } else {
                // empty queue: idle poll; partial batch: sleep exactly
                // until the oldest request's flush deadline
                let wait = batcher
                    .time_until_flush(Instant::now())
                    .unwrap_or(Duration::from_millis(50))
                    .min(Duration::from_millis(50));
                match rx.recv_timeout(wait) {
                    Ok(m) => m,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        if batcher.is_empty() {
                            break;
                        }
                        continue; // deadline reached: re-check readiness
                    }
                    Err(_) => {
                        shutting_down = true;
                        break;
                    }
                }
            };
            match msg {
                Msg::Infer(req, resp_tx) => {
                    let (id, enqueued) = (req.id, req.enqueued);
                    if batcher.push(req) {
                        waiters.insert(id, resp_tx);
                        enqueue_times.insert(id, enqueued);
                        drained += 1;
                    }
                    // else backpressure: resp_tx drops here, so the
                    // client sees a closed channel instead of hanging
                    // (rejected counter lives in the batcher)
                }
                Msg::Shutdown => {
                    shutting_down = true;
                }
            }
        }

        // 2. form + run batches
        let now = Instant::now();
        // when shutting down, flush whatever is left regardless of wait
        let deadline_now = if shutting_down {
            now + Duration::from_secs(3600)
        } else {
            now
        };
        let t_asm = Instant::now();
        if let Some(batch) = batcher.next_batch(deadline_now) {
            // assembly span: pops, input concatenation, tail padding
            let assemble_s = t_asm.elapsed().as_secs_f64();
            let logits = model
                .run_batch(&batch.data, batch.padded)
                .context("batch execution")
                .expect("model run");
            let out = model.out_elems();
            let done = Instant::now();
            // record metrics BEFORE responding so a client that has all
            // its responses also sees the final counters
            let lats: Vec<f64> = batch
                .ids
                .iter()
                .map(|id| {
                    (done - enqueue_times.remove(id).unwrap_or(done)).as_secs_f64()
                })
                .collect();
            metrics.record_batch(batch.rows, batch.padded, &lats);
            // trace: queue wait + assembly + the model's per-layer spans
            let mut spans = Vec::with_capacity(2);
            spans.push(Span::queue(batch.oldest_wait.as_secs_f64()));
            spans.push(Span::assemble(
                assemble_s,
                (batch.data.len() * std::mem::size_of::<f32>()) as u64,
            ));
            spans.extend(model.layer_spans());
            metrics.traces().push(BatchTrace {
                seq: metrics.batches(),
                ids: batch.ids.clone(),
                spans,
            });
            for (row, id) in batch.ids.iter().enumerate() {
                let lat = Duration::from_secs_f64(lats[row]);
                if let Some(tx) = waiters.remove(id) {
                    let l = logits[row * out..(row + 1) * out].to_vec();
                    let argmax = l
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    let _ = tx.send(Response { id: *id, logits: l, argmax, latency: lat });
                }
            }
        } else if shutting_down && batcher.is_empty() {
            if let Some(stem) = &cfg.obs_dump {
                dump_obs(stem, model.as_ref(), &metrics);
            }
            return;
        }
    }
}

/// Write the final telemetry snapshot next to `stem`: `<stem>.json`
/// (an `engine::json` document that round-trips through
/// `Snapshot::from_json`) and `<stem>.prom` (Prometheus text).  The
/// server-side snapshot is grafted with the model's own engine-side
/// snapshot when it has one (per-layer drift, repack edges, ...).
fn dump_obs(stem: &std::path::Path, model: &dyn BatchModel, metrics: &Metrics) {
    let mut snap = metrics.snapshot();
    if let Some(eng) = model.obs_snapshot() {
        snap.absorb_engine(&eng);
    }
    // format! instead of Path::with_extension: stems with dots in the
    // final component would lose them
    let json_path = format!("{}.json", stem.display());
    let prom_path = format!("{}.prom", stem.display());
    let mut doc = snap.to_json().to_string();
    doc.push('\n');
    if let Err(e) = std::fs::write(&json_path, doc) {
        eprintln!("obs_dump: failed to write {json_path}: {e}");
    }
    if let Err(e) = std::fs::write(&prom_path, snap.to_prometheus()) {
        eprintln!("obs_dump: failed to write {prom_path}: {e}");
    }
}

/// A trivial in-process model for tests: logits[j] = sum(input) + j.
pub struct MockModel {
    pub row_elems: usize,
    pub out_elems: usize,
    pub delay: Duration,
}

impl BatchModel for MockModel {
    fn run_batch(&mut self, data: &[f32], padded: usize) -> Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mut out = Vec::with_capacity(padded * self.out_elems);
        for r in 0..padded {
            let s: f32 =
                data[r * self.row_elems..(r + 1) * self.row_elems].iter().sum();
            for j in 0..self.out_elems {
                out.push(s + j as f32);
            }
        }
        Ok(out)
    }

    fn row_elems(&self) -> usize {
        self.row_elems
    }

    fn out_elems(&self) -> usize {
        self.out_elems
    }

    fn buckets(&self) -> Vec<usize> {
        vec![8, 32]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_server() -> InferenceServer {
        InferenceServer::start(ServerConfig::default(), || {
            Ok(Box::new(MockModel {
                row_elems: 4,
                out_elems: 3,
                delay: Duration::ZERO,
            }))
        })
    }

    #[test]
    fn serves_single_request() {
        let srv = mock_server();
        let resp = srv.submit(vec![1.0, 2.0, 3.0, 4.0]).recv().unwrap();
        assert_eq!(resp.logits, vec![10.0, 11.0, 12.0]);
        assert_eq!(resp.argmax, 2);
    }

    #[test]
    fn serves_many_and_batches() {
        let srv = mock_server();
        let inputs: Vec<Vec<f32>> = (0..100).map(|i| vec![i as f32; 4]).collect();
        let resps = srv.submit_all(inputs);
        assert_eq!(resps.len(), 100);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.logits[0], (i * 4) as f32);
        }
        assert!(srv.metrics.batches() >= 4, "work was batched");
        assert!(srv.metrics.completed() == 100);
    }

    #[test]
    fn burst_larger_than_largest_bucket_is_fully_served() {
        // regression: a burst bigger than the largest bucket (32 for
        // MockModel) must split across batches and the tail must flush
        // via the partial-flush timer — every request gets an answer.
        let srv = mock_server();
        let inputs: Vec<Vec<f32>> = (0..100).map(|i| vec![i as f32; 4]).collect();
        let resps = srv.submit_all(inputs);
        assert_eq!(resps.len(), 100);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.logits[0], (i * 4) as f32, "request {i} answered wrongly");
        }
        // 100 requests over buckets [8, 32] needs at least 4 batches
        assert!(srv.metrics.batches() >= 4);
        assert_eq!(srv.metrics.completed(), 100);
    }

    #[test]
    fn overflow_rejects_with_closed_channel_instead_of_hanging() {
        // regression: a rejected (over-capacity) request used to leak
        // its waiter, so the client blocked forever.  Now the response
        // sender drops and the client sees a closed channel.
        let srv = InferenceServer::start(
            ServerConfig {
                max_wait: Duration::from_millis(2),
                queue_capacity: 8,
                ..Default::default()
            },
            || {
                Ok(Box::new(MockModel {
                    row_elems: 4,
                    out_elems: 3,
                    // slow model so the queue genuinely backs up
                    delay: Duration::from_millis(20),
                }) as Box<dyn BatchModel>)
            },
        );
        let rxs: Vec<_> = (0..60).map(|i| srv.submit(vec![i as f32; 4])).collect();
        let mut served = 0usize;
        let mut rejected = 0usize;
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(_) => served += 1,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => rejected += 1,
                Err(e) => panic!("request neither served nor rejected: {e:?}"),
            }
        }
        assert_eq!(served + rejected, 60);
        assert!(served >= 8, "some requests must be served (got {served})");
        assert_eq!(srv.metrics.completed(), served as u64);
    }

    #[test]
    fn traces_batches_and_dumps_snapshot_on_shutdown() {
        let stem = std::env::temp_dir()
            .join(format!("tcbnn-obs-test-{}", std::process::id()));
        let srv = InferenceServer::start(
            ServerConfig { obs_dump: Some(stem.clone()), ..Default::default() },
            || {
                Ok(Box::new(MockModel {
                    row_elems: 4,
                    out_elems: 3,
                    delay: Duration::ZERO,
                }) as Box<dyn BatchModel>)
            },
        );
        let resps = srv.submit_all((0..8).map(|i| vec![i as f32; 4]).collect());
        assert_eq!(resps.len(), 8);
        assert!(srv.metrics.traces().pushed() >= 1, "batch was traced");
        let t = srv.metrics.traces().find_request(0).expect("request 0 traced");
        use crate::obs::trace::SpanKind;
        assert_eq!(t.spans[0].kind, SpanKind::Queue);
        assert_eq!(t.spans[1].kind, SpanKind::Assemble);
        assert!(t.spans[1].bytes > 0, "assembly bytes recorded");
        srv.shutdown();
        // shutdown wrote <stem>.json + <stem>.prom; JSON parses and
        // round-trips through the snapshot type
        let json_path = format!("{}.json", stem.display());
        let prom_path = format!("{}.prom", stem.display());
        let text = std::fs::read_to_string(&json_path).expect("json dumped");
        let parsed = crate::engine::json::Value::parse(&text).expect("valid JSON");
        let snap = Snapshot::from_json(&parsed).expect("snapshot shape");
        assert_eq!(snap.requests, 8);
        assert!(snap.traces_pushed >= 1);
        let prom = std::fs::read_to_string(&prom_path).expect("prom dumped");
        assert!(prom.contains("tcbnn_requests_total 8"), "{prom}");
        let _ = std::fs::remove_file(&json_path);
        let _ = std::fs::remove_file(&prom_path);
    }

    #[test]
    fn shutdown_flushes_tail() {
        let srv = mock_server();
        let rx = srv.submit(vec![0.5; 4]);
        srv.shutdown();
        // the pending request must still have been answered
        let r = rx.recv().expect("flushed on shutdown");
        assert_eq!(r.logits[0], 2.0);
    }
}
