//! The inference server: router + batcher + worker loop.
//!
//! The worker thread owns the PJRT engine (the xla client is not Send +
//! Sync, so it is constructed inside the worker — matching the paper's
//! one-process-per-GPU topology).  Clients submit requests through a
//! channel and receive responses on per-request channels.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{Batcher, BatcherConfig, Request};
use super::metrics::Metrics;

/// A batch-executing model.  Implementations: the PJRT MLP (serve_mnist)
/// and the in-process mock used by coordinator tests.
pub trait BatchModel {
    /// Execute `padded` rows of `row_elems` floats; return logits
    /// (padded x out_elems, row-major).
    fn run_batch(&mut self, data: &[f32], padded: usize) -> Result<Vec<f32>>;
    fn row_elems(&self) -> usize;
    fn out_elems(&self) -> usize;
    /// Batch sizes this model was compiled for.
    fn buckets(&self) -> Vec<usize>;
}

/// One response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub argmax: usize,
    pub latency: Duration,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub max_wait: Duration,
    pub queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_wait: Duration::from_millis(2), queue_capacity: 8192 }
    }
}

enum Msg {
    Infer(Request, Sender<Response>),
    Shutdown,
}

/// Handle used by clients to talk to a running server.
pub struct InferenceServer {
    tx: Sender<Msg>,
    pub metrics: Arc<Metrics>,
    worker: Option<JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl InferenceServer {
    /// Start the worker.  `factory` builds the model inside the worker
    /// thread (PJRT clients are not Send).
    pub fn start<F>(cfg: ServerConfig, factory: F) -> InferenceServer
    where
        F: FnOnce() -> Result<Box<dyn BatchModel>> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let m2 = Arc::clone(&metrics);
        let worker = std::thread::Builder::new()
            .name("tcbnn-server".into())
            .spawn(move || worker_loop(cfg, factory, rx, m2))
            .expect("spawn server worker");
        InferenceServer {
            tx,
            metrics,
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Submit one request; returns the channel the response arrives on.
    pub fn submit(&self, input: Vec<f32>) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req = Request { id, input, enqueued: Instant::now() };
        let _ = self.tx.send(Msg::Infer(req, rtx));
        rrx
    }

    /// Submit many inputs and wait for all responses (closed loop).
    pub fn submit_all(&self, inputs: Vec<Vec<f32>>) -> Vec<Response> {
        let receivers: Vec<Receiver<Response>> =
            inputs.into_iter().map(|x| self.submit(x)).collect();
        receivers
            .into_iter()
            .map(|r| r.recv().expect("server alive"))
            .collect()
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop<F>(
    cfg: ServerConfig,
    factory: F,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
) where
    F: FnOnce() -> Result<Box<dyn BatchModel>>,
{
    let mut model = factory().expect("model factory");
    let bcfg = BatcherConfig {
        buckets: model.buckets(),
        max_wait: cfg.max_wait,
        row_elems: model.row_elems(),
        capacity: cfg.queue_capacity,
    };
    let mut batcher = Batcher::new(bcfg);
    let mut waiters: std::collections::HashMap<u64, Sender<Response>> =
        std::collections::HashMap::new();
    let mut enqueue_times: std::collections::HashMap<u64, Instant> =
        std::collections::HashMap::new();
    let mut shutting_down = false;

    loop {
        // 1. drain the channel.  Three modes:
        //    * a batch is ready (or we're shutting down): non-blocking
        //      drain, bounded so a sustained flood cannot starve batch
        //      formation;
        //    * partial batch pending: sleep until its flush deadline
        //      (no busy-spin), waking early on new arrivals;
        //    * idle: block briefly.
        let mut drained = 0usize;
        loop {
            let batch_due = shutting_down || batcher.ready(Instant::now());
            if batch_due && drained >= 4096 {
                break; // bounded drain: go run the ready batch
            }
            let msg = if batch_due {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(_) => {
                        shutting_down = true;
                        break;
                    }
                }
            } else {
                // empty queue: idle poll; partial batch: sleep exactly
                // until the oldest request's flush deadline
                let wait = batcher
                    .time_until_flush(Instant::now())
                    .unwrap_or(Duration::from_millis(50))
                    .min(Duration::from_millis(50));
                match rx.recv_timeout(wait) {
                    Ok(m) => m,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        if batcher.is_empty() {
                            break;
                        }
                        continue; // deadline reached: re-check readiness
                    }
                    Err(_) => {
                        shutting_down = true;
                        break;
                    }
                }
            };
            match msg {
                Msg::Infer(req, resp_tx) => {
                    let (id, enqueued) = (req.id, req.enqueued);
                    if batcher.push(req) {
                        waiters.insert(id, resp_tx);
                        enqueue_times.insert(id, enqueued);
                        drained += 1;
                    }
                    // else backpressure: resp_tx drops here, so the
                    // client sees a closed channel instead of hanging
                    // (rejected counter lives in the batcher)
                }
                Msg::Shutdown => {
                    shutting_down = true;
                }
            }
        }

        // 2. form + run batches
        let now = Instant::now();
        // when shutting down, flush whatever is left regardless of wait
        let deadline_now = if shutting_down {
            now + Duration::from_secs(3600)
        } else {
            now
        };
        if let Some(batch) = batcher.next_batch(deadline_now) {
            let logits = model
                .run_batch(&batch.data, batch.padded)
                .context("batch execution")
                .expect("model run");
            let out = model.out_elems();
            let done = Instant::now();
            // record metrics BEFORE responding so a client that has all
            // its responses also sees the final counters
            let lats: Vec<f64> = batch
                .ids
                .iter()
                .map(|id| {
                    (done - enqueue_times.remove(id).unwrap_or(done)).as_secs_f64()
                })
                .collect();
            metrics.record_batch(batch.rows, batch.padded, &lats);
            for (row, id) in batch.ids.iter().enumerate() {
                let lat = Duration::from_secs_f64(lats[row]);
                if let Some(tx) = waiters.remove(id) {
                    let l = logits[row * out..(row + 1) * out].to_vec();
                    let argmax = l
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    let _ = tx.send(Response { id: *id, logits: l, argmax, latency: lat });
                }
            }
        } else if shutting_down && batcher.is_empty() {
            return;
        }
    }
}

/// A trivial in-process model for tests: logits[j] = sum(input) + j.
pub struct MockModel {
    pub row_elems: usize,
    pub out_elems: usize,
    pub delay: Duration,
}

impl BatchModel for MockModel {
    fn run_batch(&mut self, data: &[f32], padded: usize) -> Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mut out = Vec::with_capacity(padded * self.out_elems);
        for r in 0..padded {
            let s: f32 =
                data[r * self.row_elems..(r + 1) * self.row_elems].iter().sum();
            for j in 0..self.out_elems {
                out.push(s + j as f32);
            }
        }
        Ok(out)
    }

    fn row_elems(&self) -> usize {
        self.row_elems
    }

    fn out_elems(&self) -> usize {
        self.out_elems
    }

    fn buckets(&self) -> Vec<usize> {
        vec![8, 32]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_server() -> InferenceServer {
        InferenceServer::start(ServerConfig::default(), || {
            Ok(Box::new(MockModel {
                row_elems: 4,
                out_elems: 3,
                delay: Duration::ZERO,
            }))
        })
    }

    #[test]
    fn serves_single_request() {
        let srv = mock_server();
        let resp = srv.submit(vec![1.0, 2.0, 3.0, 4.0]).recv().unwrap();
        assert_eq!(resp.logits, vec![10.0, 11.0, 12.0]);
        assert_eq!(resp.argmax, 2);
    }

    #[test]
    fn serves_many_and_batches() {
        let srv = mock_server();
        let inputs: Vec<Vec<f32>> = (0..100).map(|i| vec![i as f32; 4]).collect();
        let resps = srv.submit_all(inputs);
        assert_eq!(resps.len(), 100);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.logits[0], (i * 4) as f32);
        }
        assert!(srv.metrics.batches() >= 4, "work was batched");
        assert!(srv.metrics.completed() == 100);
    }

    #[test]
    fn burst_larger_than_largest_bucket_is_fully_served() {
        // regression: a burst bigger than the largest bucket (32 for
        // MockModel) must split across batches and the tail must flush
        // via the partial-flush timer — every request gets an answer.
        let srv = mock_server();
        let inputs: Vec<Vec<f32>> = (0..100).map(|i| vec![i as f32; 4]).collect();
        let resps = srv.submit_all(inputs);
        assert_eq!(resps.len(), 100);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.logits[0], (i * 4) as f32, "request {i} answered wrongly");
        }
        // 100 requests over buckets [8, 32] needs at least 4 batches
        assert!(srv.metrics.batches() >= 4);
        assert_eq!(srv.metrics.completed(), 100);
    }

    #[test]
    fn overflow_rejects_with_closed_channel_instead_of_hanging() {
        // regression: a rejected (over-capacity) request used to leak
        // its waiter, so the client blocked forever.  Now the response
        // sender drops and the client sees a closed channel.
        let srv = InferenceServer::start(
            ServerConfig { max_wait: Duration::from_millis(2), queue_capacity: 8 },
            || {
                Ok(Box::new(MockModel {
                    row_elems: 4,
                    out_elems: 3,
                    // slow model so the queue genuinely backs up
                    delay: Duration::from_millis(20),
                }) as Box<dyn BatchModel>)
            },
        );
        let rxs: Vec<_> = (0..60).map(|i| srv.submit(vec![i as f32; 4])).collect();
        let mut served = 0usize;
        let mut rejected = 0usize;
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(_) => served += 1,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => rejected += 1,
                Err(e) => panic!("request neither served nor rejected: {e:?}"),
            }
        }
        assert_eq!(served + rejected, 60);
        assert!(served >= 8, "some requests must be served (got {served})");
        assert_eq!(srv.metrics.completed(), served as u64);
    }

    #[test]
    fn shutdown_flushes_tail() {
        let srv = mock_server();
        let rx = srv.submit(vec![0.5; 4]);
        srv.shutdown();
        // the pending request must still have been answered
        let r = rx.recv().expect("flushed on shutdown");
        assert_eq!(r.logits[0], 2.0);
    }
}
