//! Interconnect models for BENN scaling (§7.6): intra-node PCIe with
//! NCCL ring reduction, and inter-node InfiniBand with MPI_Reduce.
//!
//! The paper's testbed: 8 nodes x 8 RTX-2080Ti, PCIe inside a node,
//! IB between nodes.  Scale-up merges over NCCL (cheap); scale-out over
//! MPI (latency-heavy) — Figs 27/28's contrast.

/// One interconnect fabric.
#[derive(Clone, Copy, Debug)]
pub struct Fabric {
    pub name: &'static str,
    /// per-message software + wire latency, seconds
    pub latency_s: f64,
    /// point-to-point bandwidth, bytes/second
    pub bw_bytes: f64,
    /// per-hop software overhead of the collective implementation
    pub sw_overhead_s: f64,
}

/// PCIe 3.0 x16 inside a node (≈ 12 GB/s effective) with NCCL.
pub const PCIE_NCCL: Fabric = Fabric {
    name: "PCIe+NCCL",
    latency_s: 8.0e-6,
    bw_bytes: 12.0e9,
    sw_overhead_s: 4.0e-6,
};

/// 100 Gb/s InfiniBand between nodes with MPI_Reduce (Intel MPI).
/// Calibrated to the paper's Fig 28 observation that the 8-node MPI
/// merge costs as much as the ResNet-18 inference itself: the dominant
/// terms are per-message software latency and cross-node process skew,
/// not wire bandwidth.
pub const IB_MPI: Fabric = Fabric {
    name: "IB+MPI",
    latency_s: 200.0e-6,
    bw_bytes: 10.0e9,
    sw_overhead_s: 800.0e-6,
};

impl Fabric {
    /// Time for a ring all-reduce/reduce of `bytes` over `n` peers.
    ///
    /// Ring reduction: 2*(n-1) steps, each moving bytes/n, plus the
    /// per-step latency; degenerates to 0 for n <= 1.
    pub fn reduce_time(&self, n: usize, bytes: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = (n - 1) as f64;
        let chunk = bytes as f64 / n as f64;
        self.sw_overhead_s
            + steps * (self.latency_s + chunk / self.bw_bytes)
    }

    /// Time to gather `bytes` from each of `n` peers to a root
    /// (tree gather; used for hard-bagging's argmax votes).
    pub fn gather_time(&self, n: usize, bytes: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let levels = (n as f64).log2().ceil();
        self.sw_overhead_s
            + levels * (self.latency_s + bytes as f64 / self.bw_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_peer_is_free() {
        assert_eq!(PCIE_NCCL.reduce_time(1, 1 << 20), 0.0);
        assert_eq!(IB_MPI.gather_time(1, 1 << 20), 0.0);
    }

    #[test]
    fn ib_much_slower_than_pcie_for_small_reductions() {
        // Fig 27 vs 28: the BENN merge is small (logits), so latency
        // dominates and IB+MPI >> PCIe+NCCL
        let bytes = 128 * 1000 * 4; // batch 128 x 1000 classes fp32
        for n in [2usize, 4, 8] {
            let pcie = PCIE_NCCL.reduce_time(n, bytes);
            let ib = IB_MPI.reduce_time(n, bytes);
            assert!(ib > 2.0 * pcie, "n={n}: ib {ib} pcie {pcie}");
        }
    }

    #[test]
    fn reduce_grows_with_peers() {
        let b = 1 << 20;
        assert!(IB_MPI.reduce_time(8, b) > IB_MPI.reduce_time(2, b));
        assert!(PCIE_NCCL.reduce_time(8, b) > PCIE_NCCL.reduce_time(2, b));
    }

    #[test]
    fn bandwidth_term_matters_for_big_payloads() {
        let small = PCIE_NCCL.reduce_time(8, 1024);
        let big = PCIE_NCCL.reduce_time(8, 1 << 28);
        assert!(big > 10.0 * small);
    }
}
