//! Dynamic batcher: groups requests into multiple-of-8 batches (the
//! smallest unit the bit-tensorcores accept — §7.4 measures latency at
//! batch 8 for exactly this reason), padding the tail with copies.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One queued inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// flattened input (e.g. 800 floats for the MLP)
    pub input: Vec<f32>,
    pub enqueued: Instant,
}

/// A formed batch: inputs concatenated, padded up to `padded` rows.
#[derive(Clone, Debug)]
pub struct Batch {
    pub ids: Vec<u64>,
    pub data: Vec<f32>,
    /// logical rows (== ids.len())
    pub rows: usize,
    /// rows after padding to the bucket size
    pub padded: usize,
    /// how long the batch's oldest request sat queued before formation
    /// (the queue-wait span in the batch's `obs::trace`)
    pub oldest_wait: Duration,
}

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// available batch buckets, ascending (must match compiled
    /// artifacts, e.g. [8, 32, 128])
    pub buckets: Vec<usize>,
    /// max time the oldest request may wait before we flush a partial
    /// batch
    pub max_wait: Duration,
    /// input row width (elements)
    pub row_elems: usize,
    /// queue capacity (backpressure)
    pub capacity: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            buckets: vec![8, 32, 128],
            max_wait: Duration::from_millis(2),
            row_elems: 800,
            capacity: 4096,
        }
    }
}

/// Pick the bucket for `n` ready requests: the largest bucket that is
/// fully filled, or — when flushing stragglers — the smallest bucket
/// that fits them.  `buckets` must be ascending.  This is the single
/// bucket-selection rule shared by the [`Batcher`] and the fleet's
/// per-shard queues (`serve::queue`), so both paths pad identically.
pub fn bucket_for(buckets: &[usize], n: usize, flush: bool) -> Option<usize> {
    let full = buckets.iter().rev().find(|&&b| n >= b).copied();
    if full.is_some() {
        return full;
    }
    if flush && n > 0 {
        // smallest bucket that fits the stragglers
        return buckets
            .iter()
            .find(|&&b| b >= n)
            .copied()
            .or_else(|| buckets.last().copied());
    }
    None
}

/// FIFO dynamic batcher.
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<Request>,
    pub rejected: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        assert!(!cfg.buckets.is_empty());
        assert!(cfg.buckets.windows(2).all(|w| w[0] < w[1]));
        assert!(cfg.buckets.iter().all(|b| b % 8 == 0 && *b > 0));
        Batcher { cfg, queue: VecDeque::new(), rejected: 0 }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Enqueue; returns false (rejects) when over capacity.
    pub fn push(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.cfg.capacity {
            self.rejected += 1;
            return false;
        }
        debug_assert_eq!(req.input.len(), self.cfg.row_elems);
        self.queue.push_back(req);
        true
    }

    /// Pick the bucket for `n` ready requests: the largest bucket that
    /// is fully filled, or the smallest bucket when flushing a tail.
    fn bucket_for(&self, n: usize, flush: bool) -> Option<usize> {
        bucket_for(&self.cfg.buckets, n, flush)
    }

    /// Would `next_batch(now)` produce a batch?  Used by the server
    /// worker to decide between draining and sleeping.
    pub fn ready(&self, now: Instant) -> bool {
        let Some(front) = self.queue.front() else {
            return false;
        };
        let flush = now.duration_since(front.enqueued) >= self.cfg.max_wait;
        self.bucket_for(self.queue.len(), flush).is_some()
    }

    /// Time until the oldest waiter's partial-flush deadline (zero when
    /// already due; None when the queue is empty).  Lets the worker
    /// sleep exactly long enough instead of busy-polling — so a burst
    /// larger than the largest bucket splits across batches and the
    /// tail still flushes on the *original* enqueue deadline.
    pub fn time_until_flush(&self, now: Instant) -> Option<Duration> {
        let front = self.queue.front()?;
        Some((front.enqueued + self.cfg.max_wait).saturating_duration_since(now))
    }

    /// Form the next batch if policy allows (now = current time).
    pub fn next_batch(&mut self, now: Instant) -> Option<Batch> {
        let n = self.queue.len();
        if n == 0 {
            return None;
        }
        let oldest_wait = now.duration_since(self.queue.front().unwrap().enqueued);
        let flush = oldest_wait >= self.cfg.max_wait;
        let bucket = self.bucket_for(n, flush)?;
        let take = bucket.min(n);
        let mut ids = Vec::with_capacity(take);
        let mut data = Vec::with_capacity(bucket * self.cfg.row_elems);
        for _ in 0..take {
            let r = self.queue.pop_front().unwrap();
            ids.push(r.id);
            data.extend_from_slice(&r.input);
        }
        // pad the tail with copies of the last row (results discarded)
        let last_row_start = (take - 1) * self.cfg.row_elems;
        for _ in take..bucket {
            let row: Vec<f32> =
                data[last_row_start..last_row_start + self.cfg.row_elems].to_vec();
            data.extend_from_slice(&row);
        }
        Some(Batch { ids, data, rows: take, padded: bucket, oldest_wait })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_cases;

    fn req(id: u64, t: Instant) -> Request {
        Request { id, input: vec![id as f32; 4], enqueued: t }
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            buckets: vec![8, 32],
            max_wait: Duration::from_millis(1),
            row_elems: 4,
            capacity: 64,
        }
    }

    #[test]
    fn full_bucket_forms_immediately() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        for i in 0..8 {
            assert!(b.push(req(i, t0)));
        }
        let batch = b.next_batch(t0).expect("full bucket");
        assert_eq!(batch.rows, 8);
        assert_eq!(batch.padded, 8);
        assert_eq!(batch.ids, (0..8).collect::<Vec<_>>());
        assert!(b.is_empty());
    }

    #[test]
    fn partial_waits_until_deadline() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        for i in 0..3 {
            b.push(req(i, t0));
        }
        assert!(b.next_batch(t0).is_none(), "must wait");
        let later = t0 + Duration::from_millis(2);
        let batch = b.next_batch(later).expect("deadline flush");
        assert_eq!(batch.rows, 3);
        assert_eq!(batch.padded, 8, "padded to the smallest bucket");
        assert_eq!(batch.oldest_wait, Duration::from_millis(2), "queue wait recorded");
        // padding rows replicate the last real row
        assert_eq!(batch.data.len(), 8 * 4);
        assert_eq!(&batch.data[3 * 4..4 * 4], &batch.data[7 * 4..8 * 4]);
    }

    #[test]
    fn prefers_largest_full_bucket() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        for i in 0..40 {
            b.push(req(i, t0));
        }
        let batch = b.next_batch(t0).unwrap();
        assert_eq!(batch.padded, 32);
        assert_eq!(b.len(), 8);
    }

    #[test]
    fn backpressure_rejects() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        for i in 0..64 {
            assert!(b.push(req(i, t0)));
        }
        assert!(!b.push(req(99, t0)));
        assert_eq!(b.rejected, 1);
    }

    #[test]
    fn burst_larger_than_largest_bucket_splits_without_starving_flush() {
        // regression: a 100-request burst with buckets [8, 32] must
        // split into full 32-batches immediately, and the 4-request
        // tail must flush on the ORIGINAL enqueue deadline (t0 +
        // max_wait), not a deadline reset by the earlier splits.
        let mut b = Batcher::new(BatcherConfig { capacity: 256, ..cfg() });
        let t0 = Instant::now();
        for i in 0..100 {
            assert!(b.push(req(i, t0)));
        }
        assert!(b.ready(t0), "full buckets form without waiting");
        for _ in 0..3 {
            let batch = b.next_batch(t0).expect("full 32-bucket");
            assert_eq!(batch.rows, 32);
            assert_eq!(batch.padded, 32);
        }
        // 4 stragglers: not formable yet...
        assert_eq!(b.len(), 4);
        assert!(!b.ready(t0));
        assert!(b.next_batch(t0).is_none());
        // ...but the flush clock still reads from the burst's arrival
        let wait = b.time_until_flush(t0).unwrap();
        assert!(wait <= Duration::from_millis(1), "deadline not reset: {wait:?}");
        let due = t0 + Duration::from_millis(1);
        assert!(b.ready(due));
        assert_eq!(b.time_until_flush(due), Some(Duration::ZERO));
        let tail = b.next_batch(due).expect("tail flushes at the deadline");
        assert_eq!(tail.rows, 4);
        assert_eq!(tail.padded, 8);
        assert!(b.is_empty());
        assert_eq!(b.time_until_flush(due), None);
    }

    #[test]
    fn fifo_order_property() {
        run_cases(71, 40, |rng| {
            let mut b = Batcher::new(cfg());
            let t0 = Instant::now();
            let n = 1 + rng.gen_range(60);
            for i in 0..n as u64 {
                b.push(req(i, t0));
            }
            let mut seen = Vec::new();
            let late = t0 + Duration::from_secs(1);
            while let Some(batch) = b.next_batch(late) {
                assert!(batch.padded % 8 == 0, "mult-of-8 invariant");
                assert!(batch.rows <= batch.padded);
                seen.extend(batch.ids);
            }
            assert_eq!(seen, (0..n as u64).collect::<Vec<_>>(), "FIFO order");
        });
    }
}
