//! Layer-3 inference coordinator — the serving stack around the PJRT
//! runtime.
//!
//! Request path (all rust, no python):
//!
//! ```text
//!   clients -> Router -> per-model Batcher (multiple-of-8 batches,
//!   deadline-driven) -> worker threads (PJRT executables per batch
//!   bucket) -> responses + Metrics
//! ```
//!
//! `benn` adds the §7.6 multi-GPU BENN ensemble: one worker per "GPU",
//! outputs merged through modeled NCCL/PCIe (scale-up) or MPI/IB
//! (scale-out) collectives.
//!
//! The `serve` module (crate root) layers fleet serving on top of this
//! stack: multiple named models, replica shards with work stealing,
//! token-bucket admission control, and latency-SLO-aware batch sizing.
//! See `docs/SERVING.md`.

pub mod batcher;
pub mod benn;
pub mod comm;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use router::{Policy, RouteError, Router};
pub use server::{InferenceServer, ServerConfig};
