//! Multi-model request router: the front door of the serving stack.
//!
//! Each registered model gets its own `InferenceServer` (worker thread +
//! batcher); the router dispatches by model name and exposes aggregate
//! stats.  This is the piece that turns the single-model server into the
//! "deploy several BNN variants behind one endpoint" topology (e.g. the
//! per-bucket MLPs, or the components of a BENN ensemble colocated on
//! one host).

use std::collections::HashMap;
use std::sync::mpsc::Receiver;

use anyhow::Result;

use super::server::{BatchModel, InferenceServer, Response, ServerConfig};

/// Why a submit could not be routed.  Typed (not a stringly
/// `anyhow::Error`) so callers — the fleet layer above, HTTP fronts,
/// tests — can distinguish a client mistake (unknown model name) from
/// a server lifecycle state (worker gone) without parsing messages.
/// Interops with `anyhow::Result` call sites via `?` (it implements
/// `std::error::Error`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RouteError {
    /// No model registered under the requested name.
    UnknownModel {
        requested: String,
        /// registered names, sorted — the "did you mean" payload
        registered: Vec<String>,
    },
    /// The model exists but its worker has shut down (or died), so the
    /// request channel is closed.
    Shutdown { model: String },
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel { requested, registered } => {
                write!(f, "unknown model {requested:?} (registered: {registered:?})")
            }
            RouteError::Shutdown { model } => {
                write!(f, "model {model:?} is shut down")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// Routing policy when a model has several replicas.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// round-robin over replicas
    RoundRobin,
    /// send to the replica with the fewest completed requests in flight
    /// (approximated by completed counts; cheap and contention-free)
    LeastLoaded,
}

struct Entry {
    replicas: Vec<InferenceServer>,
    next: std::sync::atomic::AtomicUsize,
}

/// The router.
pub struct Router {
    models: HashMap<String, Entry>,
    pub policy: Policy,
}

impl Router {
    pub fn new(policy: Policy) -> Router {
        Router { models: HashMap::new(), policy }
    }

    /// Register `replicas` instances of a model under `name`.
    pub fn register<F>(
        &mut self,
        name: &str,
        replicas: usize,
        cfg: ServerConfig,
        factory: F,
    ) where
        F: Fn() -> Result<Box<dyn BatchModel>> + Send + Sync + Clone + 'static,
    {
        assert!(replicas > 0);
        let servers = (0..replicas)
            .map(|_| {
                let f = factory.clone();
                InferenceServer::start(cfg.clone(), move || f())
            })
            .collect();
        self.models.insert(
            name.to_string(),
            Entry { replicas: servers, next: std::sync::atomic::AtomicUsize::new(0) },
        );
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    fn pick(&self, e: &Entry) -> usize {
        match self.policy {
            Policy::RoundRobin => {
                e.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
                    % e.replicas.len()
            }
            Policy::LeastLoaded => e
                .replicas
                .iter()
                .enumerate()
                .min_by_key(|(_, s)| s.metrics.completed())
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    /// Route one request; returns the response channel, or a typed
    /// [`RouteError`] (unknown model vs worker shut down).
    pub fn submit(
        &self,
        model: &str,
        input: Vec<f32>,
    ) -> Result<Receiver<Response>, RouteError> {
        let Some(e) = self.models.get(model) else {
            return Err(RouteError::UnknownModel {
                requested: model.to_string(),
                registered: self.model_names(),
            });
        };
        let idx = self.pick(e);
        e.replicas[idx]
            .try_submit(input)
            .ok_or_else(|| RouteError::Shutdown { model: model.to_string() })
    }

    /// Aggregate completed-request count across all models/replicas.
    pub fn total_completed(&self) -> u64 {
        self.models
            .values()
            .flat_map(|e| e.replicas.iter())
            .map(|s| s.metrics.completed())
            .sum()
    }

    /// Per-model metric report lines.
    pub fn report(&self) -> String {
        let mut lines = Vec::new();
        for name in self.model_names() {
            let e = &self.models[&name];
            for (i, s) in e.replicas.iter().enumerate() {
                lines.push(format!("{name}[{i}]: {}", s.metrics.report()));
            }
        }
        lines.join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::MockModel;
    use std::time::Duration;

    fn mock_factory(
        out: usize,
    ) -> impl Fn() -> Result<Box<dyn BatchModel>> + Send + Sync + Clone + 'static {
        move || {
            Ok(Box::new(MockModel {
                row_elems: 4,
                out_elems: out,
                delay: Duration::ZERO,
            }) as Box<dyn BatchModel>)
        }
    }

    #[test]
    fn routes_by_model_name() {
        let mut r = Router::new(Policy::RoundRobin);
        r.register("a", 1, ServerConfig::default(), mock_factory(2));
        r.register("b", 1, ServerConfig::default(), mock_factory(5));
        let ra = r.submit("a", vec![1.0; 4]).unwrap().recv().unwrap();
        let rb = r.submit("b", vec![1.0; 4]).unwrap().recv().unwrap();
        assert_eq!(ra.logits.len(), 2);
        assert_eq!(rb.logits.len(), 5);
        assert_eq!(r.model_names(), vec!["a", "b"]);
    }

    #[test]
    fn unknown_model_rejected_with_typed_error() {
        let mut r = Router::new(Policy::RoundRobin);
        r.register("real", 1, ServerConfig::default(), mock_factory(2));
        match r.submit("nope", vec![]) {
            Err(RouteError::UnknownModel { requested, registered }) => {
                assert_eq!(requested, "nope");
                assert_eq!(registered, vec!["real".to_string()]);
            }
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        // the error interops with anyhow call sites via `?`
        let as_anyhow: anyhow::Result<()> = (|| {
            r.submit("nope", vec![])?;
            Ok(())
        })();
        assert!(as_anyhow.unwrap_err().to_string().contains("unknown model"));
    }

    #[test]
    fn dead_worker_reports_shutdown() {
        let mut r = Router::new(Policy::RoundRobin);
        // a failing factory ends the worker cleanly; the closed request
        // channel then surfaces as the typed Shutdown variant
        r.register(
            "dying",
            1,
            ServerConfig::default(),
            || -> Result<Box<dyn BatchModel>> { Err(anyhow::anyhow!("boom")) },
        );
        // submits race the worker's exit, so poll until the channel closes
        let mut saw_shutdown = false;
        for _ in 0..500 {
            match r.submit("dying", vec![0.0; 4]) {
                Err(RouteError::Shutdown { model }) => {
                    assert_eq!(model, "dying");
                    saw_shutdown = true;
                    break;
                }
                Err(e) => panic!("unexpected error {e:?}"),
                Ok(_) => std::thread::sleep(Duration::from_millis(2)),
            }
        }
        assert!(saw_shutdown, "worker death never surfaced as Shutdown");
    }

    #[test]
    fn round_robin_spreads_replicas() {
        let mut r = Router::new(Policy::RoundRobin);
        r.register("m", 3, ServerConfig::default(), mock_factory(1));
        let rxs: Vec<_> = (0..30)
            .map(|i| r.submit("m", vec![i as f32; 4]).unwrap())
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        assert_eq!(r.total_completed(), 30);
        // every replica should have seen some work
        let e = &r.models["m"];
        for (i, s) in e.replicas.iter().enumerate() {
            assert!(s.metrics.completed() > 0, "replica {i} starved");
        }
    }

    #[test]
    fn least_loaded_policy_works() {
        let mut r = Router::new(Policy::LeastLoaded);
        r.register("m", 2, ServerConfig::default(), mock_factory(1));
        for i in 0..20 {
            let rx = r.submit("m", vec![i as f32; 4]).unwrap();
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        assert_eq!(r.total_completed(), 20);
        assert!(r.report().contains("m[0]"));
    }
}
