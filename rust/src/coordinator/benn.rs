//! BENN ensemble coordinator (§7.6): K BNN components execute
//! concurrently (one per "GPU" = worker), outputs merged by bagging or
//! boosting through a modeled collective.
//!
//! Reproduces Figs 27–28: per-component inference time (from the Turing
//! cost model) + communication time (from `comm`), for scale-up (PCIe
//! NCCL inside one node) and scale-out (IB MPI across nodes).

use crate::nn::{model_cost, ModelDef, ResidualMode, Scheme};
use crate::sim::GpuModel;

use super::comm::Fabric;

/// The three ensemble strategies of Zhu et al. evaluated in Fig 27.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ensemble {
    /// majority vote over argmax labels (tiny payload)
    HardBagging,
    /// mean of softmax/logit vectors (full logits payload)
    SoftBagging,
    /// weighted sum of logits (boosting weights applied locally)
    Boosting,
}

impl Ensemble {
    pub fn name(&self) -> &'static str {
        match self {
            Ensemble::HardBagging => "hard-bagging",
            Ensemble::SoftBagging => "soft-bagging",
            Ensemble::Boosting => "boosting",
        }
    }

    /// Bytes each component contributes for a batch.
    pub fn payload_bytes(&self, batch: usize, classes: usize) -> usize {
        match self {
            // one int32 label per image
            Ensemble::HardBagging => batch * 4,
            // full logits
            Ensemble::SoftBagging | Ensemble::Boosting => batch * classes * 4,
        }
    }
}

/// Breakdown of one BENN inference round.
#[derive(Clone, Debug)]
pub struct BennCost {
    pub components: usize,
    pub compute_s: f64,
    pub comm_s: f64,
}

impl BennCost {
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.comm_s
    }
}

/// Cost of a K-component BENN on `fabric`.
///
/// Components run concurrently on identical GPUs, so compute time is one
/// component's inference (plus a small straggler penalty growing with
/// K); the merge is a K-way collective of the ensemble payload.
pub fn benn_cost(
    model: &ModelDef,
    batch: usize,
    gpu: &GpuModel,
    scheme: Scheme,
    components: usize,
    fabric: Fabric,
    ensemble: Ensemble,
) -> BennCost {
    let single =
        model_cost(model, batch, gpu, scheme, ResidualMode::Full, true).total_secs;
    // straggler effect: max of K iid component times (~2% spread per
    // doubling, matching the paper's near-flat compute bars)
    let straggle = 1.0 + 0.02 * (components as f64).log2().max(0.0);
    let compute = single * straggle;
    let payload = ensemble.payload_bytes(batch, model.classes);
    let comm = match ensemble {
        Ensemble::HardBagging => fabric.gather_time(components, payload),
        _ => fabric.reduce_time(components, payload),
    };
    BennCost { components, compute_s: compute, comm_s: comm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::comm::{IB_MPI, PCIE_NCCL};
    use crate::nn::model::imagenet_resnet18;
    use crate::sim::RTX2080TI;

    fn cost(n: usize, fabric: Fabric, e: Ensemble) -> BennCost {
        benn_cost(
            &imagenet_resnet18(),
            128,
            &RTX2080TI,
            Scheme::BtcFmt,
            n,
            fabric,
            e,
        )
    }

    #[test]
    fn scale_up_comm_is_tiny() {
        // Fig 27: "the communication overhead is tiny" over NCCL/PCIe
        for n in [2usize, 4, 8] {
            let c = cost(n, PCIE_NCCL, Ensemble::SoftBagging);
            assert!(
                c.comm_s < 0.15 * c.compute_s,
                "n={n}: comm {} vs compute {}",
                c.comm_s,
                c.compute_s
            );
        }
    }

    #[test]
    fn scale_out_comm_surges() {
        // Fig 28: "with 8 GPUs the communication latency is even higher
        // than the BNN inference itself" — within a factor band
        let c8 = cost(8, IB_MPI, Ensemble::SoftBagging);
        assert!(
            c8.comm_s > 0.5 * c8.compute_s,
            "comm {} compute {}",
            c8.comm_s,
            c8.compute_s
        );
        let c2 = cost(2, IB_MPI, Ensemble::SoftBagging);
        assert!(c8.comm_s > c2.comm_s);
    }

    #[test]
    fn hard_bagging_cheapest_merge() {
        let hard = cost(8, IB_MPI, Ensemble::HardBagging);
        let soft = cost(8, IB_MPI, Ensemble::SoftBagging);
        assert!(hard.comm_s < soft.comm_s);
    }

    #[test]
    fn compute_nearly_flat_in_k() {
        let c1 = cost(1, PCIE_NCCL, Ensemble::Boosting);
        let c8 = cost(8, PCIE_NCCL, Ensemble::Boosting);
        assert!(c8.compute_s < c1.compute_s * 1.1);
    }
}
