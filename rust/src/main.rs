//! tcbnn CLI — leader entrypoint.
//!
//! Subcommands:
//!   info                          environment + artifact status
//!   models                        Table 5 model inventory
//!   figures [--out results]       regenerate every paper table/figure
//!   infer [--n 256]               run the served MLP over the test set
//!   serve [--requests 2048]       closed-loop serving benchmark
//!   characterize [--gpu 2080ti]   §4 microbenchmark tables

fn main() {
    if let Err(e) = cli::main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

mod cli {
    use anyhow::{bail, Result};
    use tcbnn::coordinator::server::{BatchModel, InferenceServer, ServerConfig};
    use tcbnn::runtime::{Blob, MlpModel};
    use tcbnn::util::cli::Args;

    pub fn main() -> Result<()> {
        let args = Args::from_env();
        match args.subcommand() {
            Some("info") | None => info(),
            Some("models") => models(),
            Some("figures") => figures(&args),
            Some("infer") => infer(&args),
            Some("serve") => serve(&args),
            Some("characterize") => characterize(&args),
            Some(other) => {
                bail!(
                    "unknown subcommand {other:?}\n\
                     usage: tcbnn [info|models|figures|infer|serve|characterize]"
                );
            }
        }
    }

    fn info() -> Result<()> {
        println!("tcbnn — Bit-Tensor-Core BNN inference stack");
        let dir = tcbnn::artifact_dir();
        println!("artifact dir: {dir}");
        let built = std::path::Path::new(&format!("{dir}/manifest.txt")).exists();
        println!("artifacts built: {built} (run `make artifacts` if false)");
        for gpu in tcbnn::sim::config::all_gpus() {
            println!(
                "simulated GPU: {} ({}) — {} SMs, peak BTC {:.0} TOPS, \
                 peak HMMA {:.0} TFLOPS",
                gpu.name,
                gpu.chip,
                gpu.sms,
                gpu.peak_btc_tops(),
                gpu.peak_hmma_tflops()
            );
        }
        Ok(())
    }

    fn models() -> Result<()> {
        println!("{}", tcbnn::figures::table5().render());
        Ok(())
    }

    fn figures(args: &Args) -> Result<()> {
        let out = args.get_or("out", "results");
        let paths = tcbnn::figures::write_all(out)?;
        println!("wrote {} csv files under {out}/", paths.len());
        Ok(())
    }

    fn infer(args: &Args) -> Result<()> {
        let dir = tcbnn::artifact_dir();
        let n = args.get_usize("n", 256);
        let test = Blob::load(&format!("{dir}/testset"))?;
        let images = test.as_f32("images")?;
        let labels = test.as_i32("labels")?;
        let n = n.min(labels.len());
        let mut model = MlpModel::load(&dir)?;
        let t0 = std::time::Instant::now();
        let mut correct = 0usize;
        for i in (0..n).step_by(128) {
            let take = 128.min(n - i);
            let mut batch = images[i * 800..(i + take) * 800].to_vec();
            batch.resize(128 * 800, 0.0);
            let logits = model.infer(&batch, 128)?;
            for r in 0..take {
                let row = &logits[r * 10..(r + 1) * 10];
                let am = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if am as i32 == labels[i + r] {
                    correct += 1;
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "inferred {n} images in {:.1} ms — accuracy {:.2}% — {:.0} img/s",
            dt * 1e3,
            correct as f64 / n as f64 * 100.0,
            n as f64 / dt
        );
        Ok(())
    }

    fn serve(args: &Args) -> Result<()> {
        let dir = tcbnn::artifact_dir();
        let requests = args.get_usize("requests", 2048);
        let test = Blob::load(&format!("{dir}/testset"))?;
        let images = test.as_f32("images")?;
        let total = images.len() / 800;
        let dir2 = dir.clone();
        let srv = InferenceServer::start(ServerConfig::default(), move || {
            Ok(Box::new(MlpModel::load(&dir2)?) as Box<dyn BatchModel>)
        });
        let inputs: Vec<Vec<f32>> = (0..requests)
            .map(|i| {
                let j = i % total;
                images[j * 800..(j + 1) * 800].to_vec()
            })
            .collect();
        let t0 = std::time::Instant::now();
        let resps = srv.submit_all(inputs);
        let dt = t0.elapsed().as_secs_f64();
        println!("served {} requests in {:.1} ms", resps.len(), dt * 1e3);
        println!("{}", srv.metrics.report());
        Ok(())
    }

    fn characterize(args: &Args) -> Result<()> {
        let gpu = match args.get_or("gpu", "2080ti") {
            "2080" => &tcbnn::sim::RTX2080,
            _ => &tcbnn::sim::RTX2080TI,
        };
        println!("{}", tcbnn::figures::fig_load_latency(gpu).render());
        println!("{}", tcbnn::figures::fig_store_latency(gpu).render());
        println!("{}", tcbnn::figures::fig_bmma_pipeline(gpu).render());
        Ok(())
    }
}
