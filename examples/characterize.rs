//! Domain example 4: the §4 BTC characterization microbenchmarks
//! (Figs 2-13) on both simulated Turing GPUs.
//!
//!   cargo run --release --example characterize
//!
//! Shows the three §4 findings:
//!   * ldm=128 and the 128+256k family are the fast strides (Figs 2-5);
//!   * stores show no stride pattern (Figs 6-9);
//!   * bmma_sync pipelines at 4 cycles/op, 10 with a shared accumulator
//!     (Figs 10-13) — and what WLP that implies for saturation.

use tcbnn::figures;
use tcbnn::sim::{config::all_gpus, tensorcore};

fn main() {
    for gpu in all_gpus() {
        println!("{}", figures::fig_load_latency(gpu).render());
        println!("{}", figures::fig_store_latency(gpu).render());
        println!("{}", figures::fig_bmma_pipeline(gpu).render());
        println!(
            "{}: warps to saturate BMMA pipeline: {:.1} (different acc), \
             {:.1} (same acc) of {} warp slots/SM\n",
            gpu.name,
            tensorcore::warps_to_saturate(gpu, false),
            tensorcore::warps_to_saturate(gpu, true),
            gpu.max_warps_per_sm
        );
    }
}
