//! Domain example 3: BENN multi-GPU ensembles (§7.6, Figs 27-28).
//!
//!   cargo run --release --example benn_ensemble
//!
//! Scales a ResNet-18 BENN up (8 GPUs in a node over PCIe/NCCL) and out
//! (8 nodes over IB/MPI), printing the compute/communication breakdown
//! that reproduces the paper's contrast: NCCL merges are nearly free,
//! MPI merges come to dominate.

use tcbnn::coordinator::benn::{benn_cost, Ensemble};
use tcbnn::coordinator::comm::{IB_MPI, PCIE_NCCL};
use tcbnn::nn::model::imagenet_resnet18;
use tcbnn::nn::Scheme;
use tcbnn::sim::RTX2080TI;
use tcbnn::util::table::Table;

fn main() {
    let model = imagenet_resnet18();
    let batch = 128;
    for (fabric, label) in [
        (PCIE_NCCL, "Fig 27 scale-UP: 1 node, K GPUs over PCIe + NCCL"),
        (IB_MPI, "Fig 28 scale-OUT: K nodes, 1 GPU each over IB + MPI"),
    ] {
        let mut t = Table::new(label, &["gpus", "ensemble", "compute_ms", "comm_ms", "comm_share%"]);
        for e in [Ensemble::HardBagging, Ensemble::SoftBagging, Ensemble::Boosting] {
            for k in [1usize, 2, 4, 8] {
                let c = benn_cost(&model, batch, &RTX2080TI, Scheme::BtcFmt, k, fabric, e);
                t.row(&[
                    k.to_string(),
                    e.name().to_string(),
                    format!("{:.3}", c.compute_s * 1e3),
                    format!("{:.3}", c.comm_s * 1e3),
                    format!("{:.1}", c.comm_s / c.total_s() * 100.0),
                ]);
            }
        }
        println!("{}", t.render());
    }
    println!(
        "takeaway: BENN accuracy boosting is ~free inside a node; across \
         nodes the MPI merge dominates — communication is key to BENN design."
    );
}
