//! END-TO-END DRIVER (the required full-stack validation).
//!
//!   make artifacts && cargo run --release --example serve_mnist
//!
//! Exercises all three layers on a real small workload:
//!   L1  Pallas XOR/POPC bit kernels  (inside the AOT HLO)
//!   L2  the JAX BNN-MLP graph, trained with STE on synthetic MNIST
//!   L3  this rust coordinator: router -> dynamic batcher -> PJRT worker
//!
//! Loads the trained MLP artifacts, starts the inference server, fires
//! batched requests from several client threads, and reports latency
//! percentiles, throughput and classification accuracy vs the labels
//! (plus bit-exactness vs the python oracle logits).

use std::time::{Duration, Instant};

use tcbnn::coordinator::server::{BatchModel, InferenceServer, ServerConfig};
use tcbnn::runtime::{Blob, MlpModel};

fn main() -> anyhow::Result<()> {
    let dir = tcbnn::artifact_dir();
    if !std::path::Path::new(&format!("{dir}/manifest.txt")).exists() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }

    // ---- load the test set + python oracle -----------------------------
    let test = Blob::load(&format!("{dir}/testset"))?;
    let images = test.as_f32("images")?;
    let labels = test.as_i32("labels")?;
    let oracle = Blob::load(&format!("{dir}/oracle_logits"))?.as_f32("logits")?;
    let n_images = labels.len();
    println!("loaded {} test images + python oracle logits", n_images);

    // ---- verify bit-exactness against the python oracle ----------------
    let mut model = MlpModel::load(&dir)?;
    let direct = model.infer(&images[..8 * 800], 8)?;
    let max_err = direct
        .iter()
        .zip(&oracle)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("rust-vs-python oracle max |delta| = {max_err:.2e}  (8x10 logits)");
    assert!(max_err < 1e-3, "three-layer contract broken");
    drop(model);

    // ---- start the serving stack ---------------------------------------
    let dir2 = dir.clone();
    let srv = InferenceServer::start(
        ServerConfig {
            max_wait: Duration::from_millis(1),
            queue_capacity: 16384,
            ..Default::default()
        },
        move || Ok(Box::new(MlpModel::load(&dir2)?) as Box<dyn BatchModel>),
    );

    // ---- fire requests from 4 client threads ---------------------------
    let requests_per_client = 1024usize;
    let t0 = Instant::now();
    let correct: usize = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let srv = &srv;
                let images = &images;
                let labels = &labels;
                s.spawn(move || {
                    let mut correct = 0usize;
                    let rxs: Vec<_> = (0..requests_per_client)
                        .map(|i| {
                            let j = (t * 7919 + i) % n_images;
                            (j, srv.submit(images[j * 800..(j + 1) * 800].to_vec()))
                        })
                        .collect();
                    for (j, rx) in rxs {
                        let r = rx.recv().expect("server alive");
                        if r.argmax as i32 == labels[j] {
                            correct += 1;
                        }
                    }
                    correct
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let wall = t0.elapsed().as_secs_f64();
    let total = 4 * requests_per_client;

    // ---- report ----------------------------------------------------------
    println!("\n=== serve_mnist end-to-end report ===");
    println!("requests      : {total}");
    println!("wall time     : {:.1} ms", wall * 1e3);
    println!("accuracy      : {:.2}%", correct as f64 / total as f64 * 100.0);
    println!("{}", srv.metrics.report());
    let s = srv.metrics.latency_summary();
    assert!(correct as f64 / total as f64 > 0.75, "accuracy degraded");
    assert!(s.p50 > 0.0);
    println!("\nall checks passed — the three-layer stack is live");
    Ok(())
}
