//! END-TO-END ENGINE DRIVER: serve a Table-5 BNN model through the
//! coordinator, backed by the planning + arena-execution engine (no
//! PJRT artifacts needed — weights are synthesized in process).
//!
//!   cargo run --release --example serve_bnn
//!   cargo run --release --example serve_bnn -- --requests 4096 --cache plan_cache
//!
//! Flow: Planner (Turing cost model, per-layer scheme selection)
//!   -> persistent JSON plan cache -> arena executor (zero per-request
//!   allocation) -> EngineModel (BatchModel) -> InferenceServer
//!   (dynamic batcher) -> metrics.

use std::time::Instant;

use tcbnn::coordinator::server::{BatchModel, InferenceServer, ServerConfig};
use tcbnn::engine::{EngineModel, PlanCache, PlanPolicy, Planner};
use tcbnn::nn::forward::random_weights;
use tcbnn::nn::model::mnist_mlp;
use tcbnn::sim::RTX2080TI;
use tcbnn::util::cli::Args;
use tcbnn::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.get_usize("requests", 2048);
    let cache_dir = args.get_or("cache", "plan_cache").to_string();

    // ---- plan (or load the cached plan) for the Table-5 MNIST MLP ----
    let model = mnist_mlp();
    let planner = Planner::new(&RTX2080TI);
    let cache = PlanCache::open(&cache_dir)?;
    let buckets = vec![8usize, 32, 128];
    let t0 = Instant::now();
    let plan = cache.get_or_plan(&planner, &model, 128);
    println!(
        "planned {} at b128 in {:.2} ms (cache: {} hit / {} miss, dir {cache_dir}/)",
        model.name,
        t0.elapsed().as_secs_f64() * 1e3,
        cache.hits(),
        cache.misses()
    );
    println!(
        "  simulated {:.0} img/s on {}; per-layer scheme mix: {}",
        plan.throughput_fps(),
        plan.gpu,
        plan.scheme_histogram()
            .iter()
            .map(|(n, c)| format!("{n}x{c}"))
            .collect::<Vec<_>>()
            .join(" ")
    );

    // ---- build the engine-backed served model ------------------------
    let mut rng = Rng::new(1234);
    let weights = random_weights(&model, &mut rng);
    let em = EngineModel::builder(&planner, &model, &weights)
        .buckets(buckets)
        .policy(PlanPolicy::Cached)
        .cache(&cache)
        .build()?;
    println!(
        "  arena: {:.1} KB pre-allocated (constant across requests)",
        em.arena_bytes() as f64 / 1024.0
    );
    let engine_metrics = em.metrics_handle();
    let mut slot = Some(em);
    let srv = InferenceServer::start(ServerConfig::default(), move || {
        Ok(Box::new(slot.take().expect("factory runs once")) as Box<dyn BatchModel>)
    });

    // ---- closed-loop load ------------------------------------------
    let inputs: Vec<Vec<f32>> = (0..requests)
        .map(|_| (0..784).map(|_| rng.next_f32() - 0.5).collect())
        .collect();
    let t1 = Instant::now();
    let resps = srv.submit_all(inputs);
    let dt = t1.elapsed().as_secs_f64();
    println!(
        "\nserved {} requests in {:.1} ms ({:.0} req/s end-to-end)",
        resps.len(),
        dt * 1e3,
        resps.len() as f64 / dt
    );
    println!("server  : {}", srv.metrics.report());
    println!(
        "engine  : {:.0} img/s over {} executed rows (padding included)",
        engine_metrics.engine_images_per_sec(),
        engine_metrics.engine_rows()
    );
    let hist = {
        let mut h = [0usize; 10];
        for r in &resps {
            h[r.argmax] += 1;
        }
        h
    };
    println!("argmax histogram: {hist:?}");
    Ok(())
}
