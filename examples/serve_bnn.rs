//! END-TO-END ENGINE DRIVER: serve a Table-5 BNN model through the
//! coordinator, backed by the planning + arena-execution engine (no
//! PJRT artifacts needed — weights are synthesized in process).
//!
//!   cargo run --release --example serve_bnn
//!   cargo run --release --example serve_bnn -- --requests 4096 --cache plan_cache
//!   cargo run --release --example serve_bnn -- --obs-dump obs-snapshot
//!
//! Flow: Planner (Turing cost model, per-layer scheme selection)
//!   -> persistent JSON plan cache -> arena executor (zero per-request
//!   allocation) -> EngineModel (BatchModel) -> InferenceServer
//!   (dynamic batcher) -> metrics.
//!
//! `--obs-dump STEM` writes `STEM.json` + `STEM.prom` observability
//! snapshots on shutdown (see docs/OBSERVABILITY.md), then re-reads the
//! JSON and fails (nonzero exit) unless it round-trips through
//! `engine::json` back to the identical value — the CI bench-smoke job
//! runs this mode and archives the snapshot.
//!
//! `--fleet` switches to the serve::Fleet demo (the CI serve-smoke
//! job): two engine-backed models x 2 replica shards sharing one
//! pre-warmed plan cache, one model under a latency SLO, steady
//! traffic plus an injected burst that token-bucket admission must
//! shed.  Fails (nonzero exit) unless the burst shed, every accepted
//! request was answered, no routing error occurred, and — with
//! `--obs-dump STEM` — each model's `STEM-<model>.json`/`.prom`
//! snapshot round-trips.  See docs/SERVING.md.
//!
//! Fleet-mode observability flags (see docs/OBSERVABILITY.md):
//!
//! * `--listen ADDR` (e.g. `127.0.0.1:0`) starts the live scrape
//!   server (`/metrics`, `/snapshot.json`, `/healthz`) and the shard
//!   health watchdog, then self-scrapes both endpoints and fails
//!   unless `/metrics` shows a live windowed request rate and
//!   `/healthz` reports every shard up;
//! * `--addr-file PATH` writes the bound address (useful with port 0);
//! * `--trace-log PATH` + `--trace-sample N` write the sampled JSONL
//!   request-trace log (1-in-N, default 16);
//! * `--hold-ms N` keeps serving light traffic for N ms before
//!   shutdown so an external scraper (the CI curl) sees live windows.

use std::sync::Arc;
use std::time::{Duration, Instant};

use tcbnn::coordinator::server::{BatchModel, InferenceServer, ServerConfig};
use tcbnn::engine::{EngineModel, PlanCache, PlanPolicy, Planner};
use tcbnn::nn::forward::random_weights;
use tcbnn::nn::model::mnist_mlp;
use tcbnn::obs::{http_get, ScrapeServer, ScrapeSource, TraceWriter};
use tcbnn::serve::{
    plan_predictor, AdmissionConfig, Fleet, FleetError, FleetModelConfig,
    SloConfig, WatchdogConfig,
};
use tcbnn::sim::RTX2080TI;
use tcbnn::util::cli::Args;
use tcbnn::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.flag("fleet") {
        return run_fleet(&args);
    }
    let requests = args.get_usize("requests", 2048);
    let cache_dir = args.get_or("cache", "plan_cache").to_string();
    let obs_dump = args.get("obs-dump").map(std::path::PathBuf::from);

    // ---- plan (or load the cached plan) for the Table-5 MNIST MLP ----
    let model = mnist_mlp();
    let planner = Planner::new(&RTX2080TI);
    let cache = PlanCache::open(&cache_dir)?;
    let buckets = vec![8usize, 32, 128];
    let t0 = Instant::now();
    let plan = cache.get_or_plan(&planner, &model, 128);
    println!(
        "planned {} at b128 in {:.2} ms (cache: {} hit / {} miss, dir {cache_dir}/)",
        model.name,
        t0.elapsed().as_secs_f64() * 1e3,
        cache.hits(),
        cache.misses()
    );
    println!(
        "  simulated {:.0} img/s on {}; per-layer scheme mix: {}",
        plan.throughput_fps(),
        plan.gpu,
        plan.scheme_histogram()
            .iter()
            .map(|(n, c)| format!("{n}x{c}"))
            .collect::<Vec<_>>()
            .join(" ")
    );

    // ---- build the engine-backed served model ------------------------
    let mut rng = Rng::new(1234);
    let weights = random_weights(&model, &mut rng);
    let em = EngineModel::builder(&planner, &model, &weights)
        .buckets(buckets)
        .policy(PlanPolicy::Cached)
        .cache(&cache)
        .build()?;
    println!(
        "  arena: {:.1} KB pre-allocated (constant across requests)",
        em.arena_bytes() as f64 / 1024.0
    );
    let engine_metrics = em.metrics_handle();
    let mut slot = Some(em);
    let cfg = ServerConfig { obs_dump: obs_dump.clone(), ..ServerConfig::default() };
    let srv = InferenceServer::start(cfg, move || {
        Ok(Box::new(slot.take().expect("factory runs once")) as Box<dyn BatchModel>)
    });

    // ---- closed-loop load ------------------------------------------
    let inputs: Vec<Vec<f32>> = (0..requests)
        .map(|_| (0..784).map(|_| rng.next_f32() - 0.5).collect())
        .collect();
    let t1 = Instant::now();
    let resps = srv.submit_all(inputs);
    let dt = t1.elapsed().as_secs_f64();
    println!(
        "\nserved {} requests in {:.1} ms ({:.0} req/s end-to-end)",
        resps.len(),
        dt * 1e3,
        resps.len() as f64 / dt
    );
    println!("server  : {}", srv.metrics.report());
    println!(
        "engine  : {:.0} img/s over {} executed rows (padding included)",
        engine_metrics.engine_images_per_sec(),
        engine_metrics.engine_rows()
    );
    let hist = {
        let mut h = [0usize; 10];
        for r in &resps {
            h[r.argmax] += 1;
        }
        h
    };
    println!("argmax histogram: {hist:?}");

    // ---- obs_dump mode: snapshot on shutdown + round-trip check ------
    srv.shutdown();
    if let Some(stem) = obs_dump {
        let json_path = format!("{}.json", stem.display());
        let prom_path = format!("{}.prom", stem.display());
        let raw = std::fs::read_to_string(&json_path)
            .map_err(|e| anyhow::anyhow!("read {json_path}: {e}"))?;
        let value = tcbnn::engine::json::Value::parse(&raw)
            .map_err(|e| anyhow::anyhow!("parse {json_path}: {e}"))?;
        let snap = tcbnn::obs::Snapshot::from_json(&value)
            .map_err(|e| anyhow::anyhow!("decode {json_path}: {e}"))?;
        anyhow::ensure!(
            snap.to_json() == value,
            "obs snapshot does not round-trip through engine::json"
        );
        anyhow::ensure!(
            snap.requests == requests as u64,
            "snapshot counted {} requests, served {requests}",
            snap.requests
        );
        println!(
            "\nobs snapshot: {json_path} + {prom_path} \
             ({} traces kept, {} dropped; {} layers attributed)",
            snap.traces_pushed.min(snap.traces_capacity),
            snap.traces_dropped,
            snap.layers.len()
        );
        for l in &snap.layers {
            println!(
                "  L{} {:<10} {:<8} calls={} secs={:.6} drift={:.2}x",
                l.index,
                l.tag,
                l.scheme,
                l.calls,
                l.secs,
                l.drift()
            );
        }
    }
    Ok(())
}

/// `--fleet`: the serve::Fleet smoke flow (CI serve-smoke job).
///
/// Two engine-backed models x 2 replica shards share one pre-warmed
/// plan cache; `mnist` sits behind a token bucket, `mnist-slo` behind
/// a p99 deadline placed between the predicted t(8) and t(32) so the
/// SLO sizer must cut the 32-bucket.  Steady paced traffic is followed
/// by an injected burst that must shed; every accepted request must be
/// answered and no routing error may occur.
fn run_fleet(args: &Args) -> anyhow::Result<()> {
    let requests = args.get_usize("requests", 512);
    let burst = args.get_usize("burst", 256);
    let cache_dir = args.get_or("cache", "plan_cache").to_string();
    let obs_dump = args.get("obs-dump").map(|s| s.to_string());
    let listen = args.get("listen").map(|s| s.to_string());
    let addr_file = args.get("addr-file").map(|s| s.to_string());
    let trace_log = args.get("trace-log").map(|s| s.to_string());
    let trace_sample = args.get_usize("trace-sample", 16) as u64;
    let hold_ms = args.get_usize("hold-ms", 0) as u64;

    let trace = match &trace_log {
        Some(path) => Some(Arc::new(TraceWriter::create(path, trace_sample)?)),
        None => None,
    };

    let model = mnist_mlp();
    let planner = Planner::new(&RTX2080TI);
    let buckets = vec![8usize, 32];

    // pre-warm the shared plan cache before any shard spawns, so every
    // replica's Cached build is a read-only hit (no concurrent
    // same-file cache writes across worker threads)
    let cache = PlanCache::open(&cache_dir)?;
    for &b in &buckets {
        cache.get_or_plan(&planner, &model, b);
    }
    println!(
        "plan cache pre-warmed at b{buckets:?}: {} hit / {} miss ({cache_dir}/)",
        cache.hits(),
        cache.misses()
    );

    // a deadline strictly between t(8) and t(32): admissible = {8}
    let t8 = planner.predict_secs(&model, 8);
    let t32 = planner.predict_secs(&model, 32);
    let deadline = Duration::from_secs_f64((t8 + t32) / 2.0);
    println!(
        "predicted service: t(8)={:.3}ms t(32)={:.3}ms -> SLO deadline {:.3}ms",
        t8 * 1e3,
        t32 * 1e3,
        deadline.as_secs_f64() * 1e3
    );

    let factory = |seed: u64| {
        let planner = planner.clone();
        let model = model.clone();
        let cache_dir = cache_dir.clone();
        let buckets = buckets.clone();
        move || {
            let weights = random_weights(&model, &mut Rng::new(seed));
            let cache = PlanCache::open(&cache_dir)?;
            let em = EngineModel::builder(&planner, &model, &weights)
                .buckets(buckets.clone())
                .policy(PlanPolicy::Cached)
                .cache(&cache)
                .build()?;
            Ok(Box::new(em) as Box<dyn BatchModel>)
        }
    };
    let mut fleet = Fleet::new();
    fleet.register(
        "mnist",
        FleetModelConfig {
            shards: 2,
            admission: AdmissionConfig {
                rate: Some(1500.0),
                burst: 64.0,
                max_queue_depth: 8192,
            },
            trace: trace.clone(),
            ..Default::default()
        },
        factory(1234),
    );
    fleet.register(
        "mnist-slo",
        FleetModelConfig {
            shards: 2,
            slo: Some(SloConfig { p99_deadline: deadline }),
            predictor: Some(plan_predictor(&planner, &model)),
            trace: trace.clone(),
            ..Default::default()
        },
        factory(4321),
    );
    let fleet = Arc::new(fleet);

    // live observability plane: health watchdog + HTTP scrape server
    let scrape = match &listen {
        Some(addr) => {
            fleet.start_watchdog(WatchdogConfig::default());
            let srv = ScrapeServer::start(
                addr,
                Arc::clone(&fleet) as Arc<dyn ScrapeSource>,
            )?;
            let bound = srv.local_addr();
            println!(
                "scrape server on http://{bound} \
                 (/metrics /snapshot.json /healthz)"
            );
            if let Some(path) = &addr_file {
                std::fs::write(path, bound.to_string())?;
            }
            Some(srv)
        }
        None => None,
    };

    let mut rng = Rng::new(99);
    let mut input =
        || -> Vec<f32> { (0..784).map(|_| rng.next_f32() - 0.5).collect() };
    let mut pending = Vec::new();
    let mut sheds_seen = 0u64;
    let mut route_errors = 0u64;

    // steady phase: paced under the token-bucket rate, alternating
    let t0 = Instant::now();
    for i in 0..requests {
        let name = if i % 2 == 0 { "mnist" } else { "mnist-slo" };
        fleet_submit(
            &fleet, name, input(), &mut pending, &mut sheds_seen,
            &mut route_errors,
        );
        if i % 8 == 7 {
            std::thread::sleep(Duration::from_millis(3));
        }
    }
    let steady_sheds = sheds_seen;
    // injected burst: well past the bucket's 64-token allowance, all at
    // once -> admission must shed most of it
    for _ in 0..burst {
        fleet_submit(
            &fleet, "mnist", input(), &mut pending, &mut sheds_seen,
            &mut route_errors,
        );
    }
    let accepted = pending.len();
    let mut answered = 0usize;
    for rx in pending {
        rx.recv_timeout(Duration::from_secs(120))
            .map_err(|e| anyhow::anyhow!("accepted request lost: {e}"))?;
        answered += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nfleet served {answered}/{} submitted in {:.1} ms \
         ({steady_sheds} steady + {} burst sheds)",
        requests + burst,
        dt * 1e3,
        sheds_seen - steady_sheds
    );
    for name in fleet.model_names() {
        println!(
            "  {name}: {} (steals={} slo_restricted={:?})",
            fleet.metrics(&name).unwrap().report(),
            fleet.steals(&name).unwrap(),
            fleet.slo_restricted(&name).unwrap()
        );
    }

    // the serve-smoke contract
    anyhow::ensure!(route_errors == 0, "{route_errors} routing errors");
    anyhow::ensure!(answered == accepted, "lost waiters");
    anyhow::ensure!(
        sheds_seen > 0,
        "the injected {burst}-burst must shed against a 64-token bucket"
    );
    let fleet_sheds = fleet.sheds("mnist").unwrap() + fleet.sheds("mnist-slo").unwrap();
    anyhow::ensure!(
        fleet_sheds == sheds_seen,
        "fleet counted {fleet_sheds} sheds, callers saw {sheds_seen}"
    );
    anyhow::ensure!(
        fleet.slo_restricted("mnist-slo") == Some(true),
        "SLO sizer failed to cut the 32-bucket (t8={t8:.6}s t32={t32:.6}s)"
    );
    let slo_snap = fleet.snapshot("mnist-slo").expect("registered");
    anyhow::ensure!(
        slo_snap.max_batch_rows == 8,
        "SLO model formed a {}-row batch past the deadline",
        slo_snap.max_batch_rows
    );

    // live-scrape contract: with traffic just served, /metrics must
    // expose a nonzero windowed rate and /healthz must be all-up
    if let Some(srv) = &scrape {
        let addr = srv.local_addr();
        let (code, metrics) = http_get(addr, "/metrics")?;
        anyhow::ensure!(code == 200, "/metrics returned {code}");
        anyhow::ensure!(
            metrics.contains("tcbnn_requests_total{model=\"mnist\"}"),
            "/metrics lacks the model-labeled cumulative counter"
        );
        let rps = prom_sample(
            &metrics,
            "tcbnn_window_requests_per_second{model=\"mnist\",window=\"10s\"}",
        )
        .ok_or_else(|| anyhow::anyhow!("/metrics lacks the windowed rate"))?;
        anyhow::ensure!(
            rps > 0.0,
            "10s windowed rate is {rps} right after serving traffic"
        );
        let (code, health) = http_get(addr, "/healthz")?;
        anyhow::ensure!(
            code == 200 && health.contains("\"healthy\":true"),
            "/healthz not all-up: {code} {health}"
        );
        let (code, doc) = http_get(addr, "/snapshot.json")?;
        anyhow::ensure!(code == 200, "/snapshot.json returned {code}");
        let v = tcbnn::engine::json::Value::parse(&doc)
            .map_err(|e| anyhow::anyhow!("parse /snapshot.json: {e}"))?;
        anyhow::ensure!(
            v.get("schema").and_then(|s| s.as_usize())
                == Some(tcbnn::obs::OBS_SCHEMA as usize),
            "/snapshot.json schema mismatch"
        );
        println!(
            "self-scrape OK: windowed rate {rps:.0} req/s, all shards up"
        );
    }

    // hold phase: keep light traffic flowing so an external scraper
    // (the CI curl loop) observes live windows before shutdown
    if hold_ms > 0 {
        println!("holding {hold_ms} ms of light traffic for external scrapes");
        let until = Instant::now() + Duration::from_millis(hold_ms);
        let mut held = Vec::new();
        while Instant::now() < until {
            fleet_submit(
                &fleet, "mnist", input(), &mut held, &mut sheds_seen,
                &mut route_errors,
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        for rx in held {
            rx.recv_timeout(Duration::from_secs(120))
                .map_err(|e| anyhow::anyhow!("hold-phase request lost: {e}"))?;
        }
    }

    // per-model obs artifacts + round-trip check (CI uploads these)
    if let Some(stem) = &obs_dump {
        for name in fleet.model_names() {
            let snap = fleet.snapshot(&name).expect("registered");
            let json_path = format!("{stem}-{name}.json");
            let prom_path = format!("{stem}-{name}.prom");
            let mut doc = snap.to_json().to_string();
            doc.push('\n');
            std::fs::write(&json_path, &doc)?;
            std::fs::write(&prom_path, snap.to_prometheus())?;
            let value = tcbnn::engine::json::Value::parse(&doc)
                .map_err(|e| anyhow::anyhow!("parse {json_path}: {e}"))?;
            let back = tcbnn::obs::Snapshot::from_json(&value)
                .map_err(|e| anyhow::anyhow!("decode {json_path}: {e}"))?;
            anyhow::ensure!(
                back.to_json() == snap.to_json(),
                "fleet obs snapshot round-trip failed for {name}"
            );
            println!(
                "obs snapshot: {json_path} + {prom_path} \
                 (sheds={} steals={} slo_hit={:.1}%)",
                snap.sheds,
                snap.steals,
                snap.slo_hit_rate() * 100.0
            );
        }
    }
    if let Some(tw) = &trace {
        tw.flush();
        anyhow::ensure!(
            tw.written() > 0,
            "trace log sampled nothing across {} requests",
            tw.seen()
        );
        println!(
            "trace log: {} requests offered, {} lines written (1-in-{})",
            tw.seen(),
            tw.written(),
            tw.sample_every()
        );
    }
    drop(scrape); // stop accepting before the fleet drains
    fleet.begin_shutdown();
    drop(fleet); // last Arc: joins the workers
    if let Some(tw) = &trace {
        tw.flush(); // shutdown drain may have written more lines
    }
    println!("fleet smoke OK");
    Ok(())
}

/// Find the value of one exposition line by its exact
/// `name{labels}` prefix.
fn prom_sample(body: &str, name_and_labels: &str) -> Option<f64> {
    body.lines().find_map(|l| {
        let rest = l.strip_prefix(name_and_labels)?;
        rest.trim().parse().ok()
    })
}

/// Submit one request, classifying the outcome: accepted (waiter
/// kept), shed by admission (expected under the burst), or a routing
/// error (must never happen in the smoke flow).
fn fleet_submit(
    fleet: &Fleet,
    name: &str,
    x: Vec<f32>,
    pending: &mut Vec<std::sync::mpsc::Receiver<tcbnn::coordinator::server::Response>>,
    sheds: &mut u64,
    errs: &mut u64,
) {
    match fleet.submit(name, x) {
        Ok(rx) => pending.push(rx),
        Err(FleetError::Overloaded(_)) => *sheds += 1,
        Err(e) => {
            eprintln!("unexpected routing error: {e}");
            *errs += 1;
        }
    }
}
