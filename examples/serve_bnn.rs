//! END-TO-END ENGINE DRIVER: serve a Table-5 BNN model through the
//! coordinator, backed by the planning + arena-execution engine (no
//! PJRT artifacts needed — weights are synthesized in process).
//!
//!   cargo run --release --example serve_bnn
//!   cargo run --release --example serve_bnn -- --requests 4096 --cache plan_cache
//!   cargo run --release --example serve_bnn -- --obs-dump obs-snapshot
//!
//! Flow: Planner (Turing cost model, per-layer scheme selection)
//!   -> persistent JSON plan cache -> arena executor (zero per-request
//!   allocation) -> EngineModel (BatchModel) -> InferenceServer
//!   (dynamic batcher) -> metrics.
//!
//! `--obs-dump STEM` writes `STEM.json` + `STEM.prom` observability
//! snapshots on shutdown (see docs/OBSERVABILITY.md), then re-reads the
//! JSON and fails (nonzero exit) unless it round-trips through
//! `engine::json` back to the identical value — the CI bench-smoke job
//! runs this mode and archives the snapshot.

use std::time::Instant;

use tcbnn::coordinator::server::{BatchModel, InferenceServer, ServerConfig};
use tcbnn::engine::{EngineModel, PlanCache, PlanPolicy, Planner};
use tcbnn::nn::forward::random_weights;
use tcbnn::nn::model::mnist_mlp;
use tcbnn::sim::RTX2080TI;
use tcbnn::util::cli::Args;
use tcbnn::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.get_usize("requests", 2048);
    let cache_dir = args.get_or("cache", "plan_cache").to_string();
    let obs_dump = args.get("obs-dump").map(std::path::PathBuf::from);

    // ---- plan (or load the cached plan) for the Table-5 MNIST MLP ----
    let model = mnist_mlp();
    let planner = Planner::new(&RTX2080TI);
    let cache = PlanCache::open(&cache_dir)?;
    let buckets = vec![8usize, 32, 128];
    let t0 = Instant::now();
    let plan = cache.get_or_plan(&planner, &model, 128);
    println!(
        "planned {} at b128 in {:.2} ms (cache: {} hit / {} miss, dir {cache_dir}/)",
        model.name,
        t0.elapsed().as_secs_f64() * 1e3,
        cache.hits(),
        cache.misses()
    );
    println!(
        "  simulated {:.0} img/s on {}; per-layer scheme mix: {}",
        plan.throughput_fps(),
        plan.gpu,
        plan.scheme_histogram()
            .iter()
            .map(|(n, c)| format!("{n}x{c}"))
            .collect::<Vec<_>>()
            .join(" ")
    );

    // ---- build the engine-backed served model ------------------------
    let mut rng = Rng::new(1234);
    let weights = random_weights(&model, &mut rng);
    let em = EngineModel::builder(&planner, &model, &weights)
        .buckets(buckets)
        .policy(PlanPolicy::Cached)
        .cache(&cache)
        .build()?;
    println!(
        "  arena: {:.1} KB pre-allocated (constant across requests)",
        em.arena_bytes() as f64 / 1024.0
    );
    let engine_metrics = em.metrics_handle();
    let mut slot = Some(em);
    let cfg = ServerConfig { obs_dump: obs_dump.clone(), ..ServerConfig::default() };
    let srv = InferenceServer::start(cfg, move || {
        Ok(Box::new(slot.take().expect("factory runs once")) as Box<dyn BatchModel>)
    });

    // ---- closed-loop load ------------------------------------------
    let inputs: Vec<Vec<f32>> = (0..requests)
        .map(|_| (0..784).map(|_| rng.next_f32() - 0.5).collect())
        .collect();
    let t1 = Instant::now();
    let resps = srv.submit_all(inputs);
    let dt = t1.elapsed().as_secs_f64();
    println!(
        "\nserved {} requests in {:.1} ms ({:.0} req/s end-to-end)",
        resps.len(),
        dt * 1e3,
        resps.len() as f64 / dt
    );
    println!("server  : {}", srv.metrics.report());
    println!(
        "engine  : {:.0} img/s over {} executed rows (padding included)",
        engine_metrics.engine_images_per_sec(),
        engine_metrics.engine_rows()
    );
    let hist = {
        let mut h = [0usize; 10];
        for r in &resps {
            h[r.argmax] += 1;
        }
        h
    };
    println!("argmax histogram: {hist:?}");

    // ---- obs_dump mode: snapshot on shutdown + round-trip check ------
    srv.shutdown();
    if let Some(stem) = obs_dump {
        let json_path = format!("{}.json", stem.display());
        let prom_path = format!("{}.prom", stem.display());
        let raw = std::fs::read_to_string(&json_path)
            .map_err(|e| anyhow::anyhow!("read {json_path}: {e}"))?;
        let value = tcbnn::engine::json::Value::parse(&raw)
            .map_err(|e| anyhow::anyhow!("parse {json_path}: {e}"))?;
        let snap = tcbnn::obs::Snapshot::from_json(&value)
            .map_err(|e| anyhow::anyhow!("decode {json_path}: {e}"))?;
        anyhow::ensure!(
            snap.to_json() == value,
            "obs snapshot does not round-trip through engine::json"
        );
        anyhow::ensure!(
            snap.requests == requests as u64,
            "snapshot counted {} requests, served {requests}",
            snap.requests
        );
        println!(
            "\nobs snapshot: {json_path} + {prom_path} \
             ({} traces kept, {} dropped; {} layers attributed)",
            snap.traces_pushed.min(snap.traces_capacity),
            snap.traces_dropped,
            snap.layers.len()
        );
        for l in &snap.layers {
            println!(
                "  L{} {:<10} {:<8} calls={} secs={:.6} drift={:.2}x",
                l.index,
                l.tag,
                l.scheme,
                l.calls,
                l.secs,
                l.drift()
            );
        }
    }
    Ok(())
}
