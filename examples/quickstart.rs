//! Quickstart: the library's core objects in ~60 lines.
//!
//!   cargo run --release --example quickstart
//!
//! 1. pack a +/-1 matrix into bits, 2. convert to the FSB format,
//! 3. run the FSB BMM (Design-3) and check it against the float result,
//! 4. ask the Turing timing model what each design would cost.

use tcbnn::bitops::{BitMatrix, FsbMatrix, Layout};
use tcbnn::kernels::bmm::{self, btc, BmmProblem, BmmScheme};
use tcbnn::kernels::IoMode;
use tcbnn::sim::{Engine, RTX2080TI};
use tcbnn::util::Rng;

fn main() {
    let mut rng = Rng::new(1);

    // ---- 1. binarize + pack (Eq 1) -------------------------------------
    let (m, n, k) = (64, 256, 512);
    let a = BitMatrix::random(m, k, Layout::RowMajor, &mut rng);
    let b = BitMatrix::random(k, n, Layout::ColMajor, &mut rng);
    println!(
        "packed A ({m}x{k}) into {} bytes — 32x smaller than f32",
        a.storage_bytes()
    );

    // ---- 2. FSB format (§5.1) ------------------------------------------
    let fsb = FsbMatrix::from_bitmatrix(&a);
    println!(
        "FSB image: {}x{} tiles of 128x8 bits, fixed ldm=128",
        fsb.tiles_y, fsb.tiles_x
    );

    // ---- 3. bit matrix multiplication (Eq 2) ---------------------------
    let d3 = btc::Design3;
    let c = d3.compute(&a, &b);
    let want = bmm::naive_ref(&a, &b);
    assert_eq!(c, want, "Design-3 must be bit-exact");
    println!("BMM ok: C[0][0..4] = {:?}", &c[..4]);

    // ---- 4. what would this cost on a Turing GPU? ----------------------
    let engine = Engine::new(&RTX2080TI);
    let p = BmmProblem { m: 4096, n: 4096, k: 4096 };
    println!("\nsimulated 4096^3 BMM on {} (BNN-specific):", engine.gpu.name);
    for scheme in bmm::all_schemes() {
        if !scheme.supports(p, IoMode::BnnSpecific) {
            continue;
        }
        let tops = bmm::simulate_tops(&engine, scheme.as_ref(), p, IoMode::BnnSpecific);
        println!("  {:<10} {:>8.1} TOPS", scheme.name(), tops);
    }
    println!("\n(quickstart done — see examples/serve_mnist.rs for the full stack)");
}
