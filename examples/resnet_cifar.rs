//! Domain example 2: a binarized conv net on Cifar-scale data, run both
//! FUNCTIONALLY (real bit arithmetic through the rust kernels) and
//! through the Turing cost model (per-layer breakdown, all schemes).
//!
//!   cargo run --release --example resnet_cifar

use tcbnn::nn::forward::{forward, random_weights};
use tcbnn::nn::layer::{Dims, LayerSpec};
use tcbnn::nn::model::cifar_resnet14;
use tcbnn::nn::{model_cost, ModelDef, ResidualMode, Scheme};
use tcbnn::sim::RTX2080TI;
use tcbnn::util::table::Table;
use tcbnn::util::Rng;

fn main() {
    // ---- functional pass: a reduced cifar net executes real bits ------
    let lite = ModelDef {
        name: "cifar-lite",
        dataset: "synthetic cifar",
        input: Dims { hw: 16, feat: 3 },
        classes: 10,
        layers: vec![
            LayerSpec::FirstConv { c: 3, o: 64, k: 3, stride: 1, pad: 1 },
            LayerSpec::BinConv {
                c: 64, o: 128, k: 3, stride: 1, pad: 1, pool: true, residual: false,
            },
            LayerSpec::BinConv {
                c: 128, o: 128, k: 3, stride: 1, pad: 1, pool: true, residual: false,
            },
            LayerSpec::BinFc { d_in: 4 * 4 * 128, d_out: 256 },
            LayerSpec::FinalFc { d_in: 256, d_out: 10 },
        ],
        residual_blocks: 0,
    };
    let mut rng = Rng::new(2024);
    let weights = random_weights(&lite, &mut rng);
    let batch = 8;
    let x: Vec<f32> = (0..batch * 16 * 16 * 3).map(|_| rng.next_f32()).collect();
    let t0 = std::time::Instant::now();
    let logits = forward(&lite, &weights, &x, batch);
    println!(
        "functional bit-forward of {} ({} layers) on batch {batch}: {:.1} ms",
        lite.name,
        lite.layers.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!("logits[img0] = {:?}\n", &logits[..10].iter().map(|v| format!("{v:.2}")).collect::<Vec<_>>());

    // ---- cost model: the real Cifar10-ResNet14 across all schemes -----
    let m = cifar_resnet14();
    let mut t = Table::new(
        "Cifar10-ResNet14, 8-image latency on RTX2080Ti (simulated)",
        &["scheme", "latency_ms", "throughput_fps(b=1024)"],
    );
    // FASTPATH is costed by the CPU host model, not the Turing
    // simulator — it has no place in a GPU-simulated table
    for s in Scheme::all().into_iter().filter(|s| *s != Scheme::Fastpath) {
        let lat = model_cost(&m, 8, &RTX2080TI, s, ResidualMode::Full, true);
        let tp = model_cost(&m, 1024, &RTX2080TI, s, ResidualMode::Full, true);
        t.row(&[
            s.name().to_string(),
            format!("{:.3}", lat.total_secs * 1e3),
            format!("{:.0}", tp.throughput_fps()),
        ]);
    }
    println!("{}", t.render());

    // ---- per-layer breakdown (Fig 24 view) ------------------------------
    let c = model_cost(&m, 8, &RTX2080TI, Scheme::BtcFmt, ResidualMode::Full, true);
    let mut bt = Table::new("per-layer breakdown (BTC-FMT)", &["layer", "us", "share%"]);
    for l in &c.layers {
        bt.row(&[
            l.tag.clone(),
            format!("{:.1}", l.secs * 1e6),
            format!("{:.1}", l.secs / c.total_secs * 100.0),
        ]);
    }
    println!("{}", bt.render());
}
