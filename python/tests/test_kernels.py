"""Pallas kernels vs pure-jnp oracles (hypothesis-swept).

The CORE L1 correctness signal: every kernel must agree exactly (bit math
is integer-exact) with ref.py over randomized shapes and contents.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import bconv, binarize, bmm, ref


def rand_pm1(rng, shape):
    return rng.choice(np.array([-1.0, 1.0], np.float32), size=shape)


# ---------------------------------------------------------------------------
# pack/unpack algebra
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(1, 12), st.integers(0, 2**31))
def test_pack_unpack_roundtrip(rows, words, seed):
    rng = np.random.default_rng(seed)
    n = words * 32
    x = rand_pm1(rng, (rows, n))
    packed = ref.pack_bits(x)
    assert packed.shape == (rows, words)
    back = ref.unpack_bits(packed, n)
    assert np.array_equal(back, x)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(0, 2**31))
def test_eq2_identity(words, seed):
    """Eq 2: pm1 dot == n - 2*popc(xor)."""
    rng = np.random.default_rng(seed)
    n = words * 32
    a = rand_pm1(rng, (n,))
    b = rand_pm1(rng, (n,))
    fdot = float(np.dot(a, b))
    pa = ref.pack_bits(a[None, :])[0]
    pb = ref.pack_bits(b[None, :])[0]
    p = int(np.bitwise_count(np.asarray(pa) ^ np.asarray(pb)).sum())
    assert n - 2 * p == int(fdot)


def test_sign_zero_is_plus_one():
    # Eq 1: x >= 0 -> +1 (zero binarizes to +1)
    assert float(ref.sign_pm1(jnp.asarray(0.0))) == 1.0
    assert float(ref.sign_pm1(jnp.asarray(-1e-9))) == -1.0


# ---------------------------------------------------------------------------
# BMM kernel
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    st.integers(1, 4),   # M tiles of 8
    st.integers(1, 3),   # N tiles of 128
    st.integers(1, 8),   # K words of 32
    st.integers(0, 2**31),
)
def test_bmm_matches_float_oracle(mt, nt, kw, seed):
    rng = np.random.default_rng(seed)
    m, n, k = mt * 8, nt * 128, kw * 32
    a = rand_pm1(rng, (m, k))
    bt = rand_pm1(rng, (n, k))  # packed columns of B
    apk, bpk = ref.pack_bits(a), ref.pack_bits(bt)
    want = ref.bmm_float_ref(a, bt.T)
    assert np.array_equal(ref.bmm_packed_ref(apk, bpk, k), want)
    assert np.array_equal(bmm.bmm(apk, bpk, k), want)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(1, 2), st.integers(0, 2**31))
def test_bmm_bin_fused_threshold(mt, nt, seed):
    rng = np.random.default_rng(seed)
    m, n, k = mt * 8, nt * 128, 64
    a = rand_pm1(rng, (m, k))
    bt = rand_pm1(rng, (n, k))
    apk, bpk = ref.pack_bits(a), ref.pack_bits(bt)
    th = rng.standard_normal(n).astype(np.float32) * 8
    fl = (rng.random(n) < 0.3).astype(np.int32)
    got = bmm.bmm_bin(apk, bpk, k, jnp.asarray(th), jnp.asarray(fl))
    # build expected from the float oracle + threshold_ref + pack
    y = np.asarray(ref.bmm_packed_ref(apk, bpk, k)).astype(np.float32)
    pm1 = np.asarray(ref.threshold_ref(jnp.asarray(y), jnp.asarray(th), jnp.asarray(fl != 0)))
    want = ref.pack_bits(pm1)
    assert np.array_equal(got, want)


def test_bmm_rejects_bad_shapes():
    a = jnp.zeros((8, 4), jnp.uint32)
    b = jnp.zeros((128, 5), jnp.uint32)
    with pytest.raises(AssertionError):
        bmm.bmm(a, b, 128)


# ---------------------------------------------------------------------------
# binarize kernel
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6), st.integers(0, 2**31))
def test_binarize_pack(rt, words, seed):
    rng = np.random.default_rng(seed)
    m, n = rt * 8, words * 32
    x = rng.standard_normal((m, n)).astype(np.float32)
    th = rng.standard_normal(n).astype(np.float32) * 0.5
    got = binarize.binarize_pack(jnp.asarray(x), jnp.asarray(th))
    want = ref.pack_bits(np.where(x >= th[None, :], 1.0, -1.0).astype(np.float32))
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# BConv kernel — the padding/exclude logic is the paper's §5.3 contribution
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(
    st.integers(4, 7),          # H == W
    st.sampled_from([1, 2]),    # stride
    st.sampled_from([0, 1, 2]), # pad
    st.integers(0, 2**31),
)
def test_bconv_matches_float_oracle(hw, stride, pad, seed):
    rng = np.random.default_rng(seed)
    kk = 3
    if (hw + 2 * pad - kk) < 0:
        return
    n, c, o = 8, 32, 8
    inp = rand_pm1(rng, (hw, hw, n, c))
    fil = rand_pm1(rng, (kk, kk, c, o))
    ipk = ref.pack_bits(inp)
    fpk = ref.pack_bits(np.transpose(fil, (0, 1, 3, 2)))
    want = ref.bconv_float_ref(inp, fil, stride, pad)
    got_ref = ref.bconv_packed_ref(np.asarray(ipk), np.asarray(fpk), c, stride, pad)
    got_pl = bconv.bconv(ipk, fpk, c, stride, pad)
    assert np.array_equal(want, got_ref)
    assert np.array_equal(want, got_pl)


def test_bconv_padding_differs_from_minus_one_padding():
    """The exclude amendment must NOT equal naive -1 padding — this is the
    bug the paper's §5.3 exists to avoid."""
    rng = np.random.default_rng(5)
    hw, kk, n, c, o = 4, 3, 8, 32, 8
    inp = rand_pm1(rng, (hw, hw, n, c))
    fil = rand_pm1(rng, (kk, kk, c, o))
    ipk = ref.pack_bits(inp)
    fpk = ref.pack_bits(np.transpose(fil, (0, 1, 3, 2)))
    ours = np.asarray(bconv.bconv(ipk, fpk, c, 1, 1))
    # naive: physically pad with -1 and convolve without exclusion
    inp_pad = np.pad(inp, ((1, 1), (1, 1), (0, 0), (0, 0)), constant_values=-1.0)
    naive = np.asarray(ref.bconv_float_ref(inp_pad, fil, 1, 0))
    # interior must agree, border must differ somewhere
    assert np.array_equal(ours[1:-1, 1:-1], naive[1:-1, 1:-1])
    assert not np.array_equal(ours, naive)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31))
def test_bconv_bin_fused(seed):
    rng = np.random.default_rng(seed)
    hw, kk, n, c, o = 4, 3, 8, 32, 32
    inp = rand_pm1(rng, (hw, hw, n, c))
    fil = rand_pm1(rng, (kk, kk, c, o))
    ipk = ref.pack_bits(inp)
    fpk = ref.pack_bits(np.transpose(fil, (0, 1, 3, 2)))
    th = rng.standard_normal(o).astype(np.float32) * 4
    fl = np.zeros(o, np.int32)
    got = bconv.bconv_bin(ipk, fpk, c, jnp.asarray(th), jnp.asarray(fl))
    y = np.asarray(bconv.bconv(ipk, fpk, c)).astype(np.float32)
    want = ref.pack_bits(np.where(y >= th[None, None, None, :], 1.0, -1.0))
    assert np.array_equal(got, want)


def test_maxpool_or_equals_float_max():
    rng = np.random.default_rng(1)
    h = w = 4
    x = rand_pm1(rng, (h, w, 8, 32))
    xpk = np.asarray(ref.pack_bits(x))
    got = np.asarray(bconv.maxpool2_or(xpk))
    want_float = x.reshape(2, 2, 2, 2, 8, 32).max(axis=(1, 3))
    assert np.array_equal(got, np.asarray(ref.pack_bits(want_float)))
