"""L2 model-graph tests: shapes, bn folding, trainer export consistency."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile.kernels import ref


def tiny_params(rng):
    """Random (untrained) weight args for mlp_forward."""
    def u32(shape):
        return jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))

    def f32(shape, scale=1.0):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)

    args = [f32((M.MLP_IN,), 0.0) + 0.5]
    for _ in range(3):
        args += [u32((M.MLP_HIDDEN, 32 if len(args) > 1 else M.MLP_IN // 32)),
                 f32((M.MLP_HIDDEN,), 4.0),
                 jnp.zeros((M.MLP_HIDDEN,), jnp.int32)]
    args += [u32((M.MLP_OUT_PAD, 32)), f32((M.MLP_OUT_PAD,), 0.1),
             f32((M.MLP_OUT_PAD,), 0.1)]
    return args


def test_mlp_forward_shape():
    rng = np.random.default_rng(0)
    args = tiny_params(rng)
    x = jnp.asarray(rng.random((8, M.MLP_IN)).astype(np.float32))
    logits = M.mlp_forward(x, *args)
    assert logits.shape == (8, M.MLP_CLASSES)
    assert logits.dtype == jnp.float32
    assert np.all(np.isfinite(np.asarray(logits)))


def test_mlp_arg_specs_match_forward():
    specs = M.mlp_arg_specs(8)
    out = jax.eval_shape(M.mlp_forward, *specs)
    assert out.shape == (8, M.MLP_CLASSES)


@pytest.mark.parametrize("batch", [8, 32])
def test_mlp_batch_row_independence(batch):
    """Each row's logits depend only on that row (batcher correctness)."""
    rng = np.random.default_rng(3)
    args = tiny_params(rng)
    x = rng.random((batch, M.MLP_IN)).astype(np.float32)
    full = np.asarray(M.mlp_forward(jnp.asarray(x), *args))
    x2 = x.copy()
    x2[batch // 2:] = rng.random((batch - batch // 2, M.MLP_IN))
    half = np.asarray(M.mlp_forward(jnp.asarray(x2), *args))
    assert np.array_equal(full[: batch // 2], half[: batch // 2])


def test_bn_threshold_fold():
    """sign(bn(x)) == threshold compare for both gamma signs."""
    rng = np.random.default_rng(1)
    n = 64
    x = jnp.asarray(rng.standard_normal((32, n)).astype(np.float32) * 10)
    mean = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    var = jnp.asarray(rng.random(n).astype(np.float32) + 0.1)
    gamma = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    beta = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    y = ref.bn_ref(x, mean, var, gamma, beta)
    want = ref.sign_pm1(y)
    tau, flip = ref.bn_to_threshold(mean, var, gamma, beta)
    got = ref.threshold_ref(x, tau, flip)
    # boundary exactness can differ at y == 0; require < 0.5% disagreement
    frac = float(jnp.mean(got != want))
    assert frac < 0.005, f"fold disagreement {frac}"


def test_conv_block_shapes():
    specs = M.conv_block_arg_specs(16, 16, 8, 128, 128)
    out = jax.eval_shape(lambda i, f, t, fl: M.conv_block_forward(i, f, t, fl, 128), *specs)
    assert out.shape == (8, 8, 8, 128 // 32)
    assert out.dtype == jnp.uint32


def test_bmm_forward_spec():
    out = jax.eval_shape(lambda a, b: M.bmm_forward(a, b, 1024), *M.bmm_arg_specs(1024, 1024, 1024))
    assert out.shape == (1024, 1024)
    assert out.dtype == jnp.int32
