"""Pure-jnp reference oracles for the bit kernels.

These implement the BNN algebra of the paper (Li & Su, "Accelerating
Binarized Neural Networks via Bit-Tensor-Cores in Turing GPUs") directly on
float / packed-uint32 arrays, with no Pallas involved.  Every Pallas kernel
in this package is pytest-verified against these functions.

Conventions (shared with the rust side, see rust/src/bitops/pack.rs):

* a binary value is +1 or -1; bit 1 encodes +1, bit 0 encodes -1 (Eq 1);
* packing is along the LAST axis, LSB-first: bit ``j`` of word ``w``
  holds element ``w*32 + j``;
* the +/-1 dot product over bit vectors is Eq 2 of the paper:
  ``v = n - 2*popc(a XOR b)``.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# binarization + packing
# ---------------------------------------------------------------------------

def sign_pm1(x):
    """Eq 1: x >= 0 -> +1.0 else -1.0 (float output)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(jnp.float32)


def pack_bits(x):
    """Pack a +/-1 (or >=0 / <0) float array along the last axis into uint32.

    The last axis length must be a multiple of 32.  Bit j of word w holds
    element w*32+j, LSB-first; bit 1 encodes +1 (x >= 0).
    """
    x = jnp.asarray(x)
    n = x.shape[-1]
    assert n % 32 == 0, f"pack_bits: last axis {n} not a multiple of 32"
    bits = (x >= 0).astype(jnp.uint32)
    bits = bits.reshape(x.shape[:-1] + (n // 32, 32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint32)


def unpack_bits(words, n):
    """Inverse of pack_bits: uint32 words -> +/-1 float array of length n."""
    words = jnp.asarray(words)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,))
    pm1 = jnp.where(flat == 1, 1.0, -1.0).astype(jnp.float32)
    return pm1[..., :n]


def popcount(x):
    """Population count of a uint32 array (elementwise)."""
    return jnp.bitwise_count(jnp.asarray(x, dtype=jnp.uint32)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# BMM (fully-connected layer)
# ---------------------------------------------------------------------------

def bmm_float_ref(a_pm1, b_pm1):
    """+/-1 matrix product on float arrays: (M,K) x (K,N) -> (M,N) int32."""
    return jnp.matmul(
        a_pm1.astype(jnp.float32), b_pm1.astype(jnp.float32)
    ).astype(jnp.int32)


def bmm_packed_ref(a_pk, b_pk, k):
    """Eq 2 BMM over packed operands.

    a_pk: (M, K/32) uint32, row-major packed rows of A.
    b_pk: (N, K/32) uint32, packed COLUMNS of B (i.e. B^T rows — the
          "column-major" operand layout the Turing BMMA expects).
    k:    the un-packed inner dimension (bit-vector length n of Eq 2).

    Returns (M, N) int32 = k - 2*popc(a XOR b).
    """
    x = jnp.bitwise_xor(a_pk[:, None, :], b_pk[None, :, :])
    p = jnp.sum(popcount(x), axis=-1)
    return (jnp.int32(k) - 2 * p).astype(jnp.int32)


def bmm_bin_ref(a_pk, b_pk, k, thresh):
    """BNN-specific BMM: Eq 2 product followed by threshold binarization
    (the fused bn+sign "thrd" op of Fig 15) and re-packing along N.

    thresh: (N,) float32 per-output-neuron threshold.
    Returns (M, N/32) uint32.
    """
    y = bmm_packed_ref(a_pk, b_pk, k).astype(jnp.float32)
    return pack_bits(jnp.where(y >= thresh[None, :], 1.0, -1.0))


# ---------------------------------------------------------------------------
# BConv (convolution layer)
# ---------------------------------------------------------------------------

def bconv_float_ref(inp_pm1, fil_pm1, stride=1, pad=1):
    """+/-1 cross-correlation with logical zero padding.

    inp_pm1: (H, W, N, C) float +/-1   (the paper's HWNC layout)
    fil_pm1: (K, K, C, O) float +/-1   (KKCO layout)
    Padded taps contribute 0 to the sum — the bit-padding problem of §5.3:
    a padded position is *excluded*, not treated as -1.

    Returns (Ho, Wo, N, O) int32.
    """
    h, w, n, c = inp_pm1.shape
    kh, kw, c2, o = fil_pm1.shape
    assert c == c2
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    out = np.zeros((ho, wo, n, o), dtype=np.int64)
    inp = np.asarray(inp_pm1, dtype=np.float64)
    fil = np.asarray(fil_pm1, dtype=np.float64)
    for p in range(ho):
        for q in range(wo):
            acc = np.zeros((n, o))
            for r in range(kh):
                for s in range(kw):
                    i = p * stride - pad + r
                    j = q * stride - pad + s
                    if 0 <= i < h and 0 <= j < w:
                        acc += inp[i, j] @ fil[r, s]
            out[p, q] = acc.astype(np.int64)
    return jnp.asarray(out, dtype=jnp.int32)


def bconv_packed_ref(inp_pk, fil_pk, c, stride=1, pad=1):
    """Packed-bit BConv with the paper's `exclude` amendment (Listing 6).

    inp_pk: (H, W, N, C/32) uint32 — HWNC packed along C.
    fil_pk: (K, K, O, C/32) uint32 — KKCO packed along C (O-major rows so
            each filter tap is a "column-major" BMM operand).
    For each output point the valid taps form a bit dot product of length
    c * n_valid; out = c*(KK - exclude) - 2 * sum(popc(xor)).
    """
    h, w, n, cp = inp_pk.shape
    kh, kw, o, cp2 = fil_pk.shape
    assert cp == cp2 and cp * 32 == c
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    inp = np.asarray(inp_pk)
    fil = np.asarray(fil_pk)
    out = np.zeros((ho, wo, n, o), dtype=np.int64)
    for p in range(ho):
        for q in range(wo):
            acc = np.zeros((n, o), dtype=np.int64)
            exclude = 0
            for r in range(kh):
                for s in range(kw):
                    i = p * stride - pad + r
                    j = q * stride - pad + s
                    if 0 <= i < h and 0 <= j < w:
                        x = inp[i, j][:, None, :] ^ fil[r, s][None, :, :]
                        acc += np.bitwise_count(x).sum(axis=-1, dtype=np.int64)
                    else:
                        exclude += 1
            n_valid = c * (kh * kw - exclude)
            out[p, q] = n_valid - 2 * acc
    return jnp.asarray(out, dtype=jnp.int32)


def maxpool2_or_ref(x_pk, h, w):
    """2x2 max-pool over packed bits == logical OR of the 4 packed words
    (§6.1: max over +/-1 == OR over the bit encoding).

    x_pk: (H, W, ...) packed uint32, H and W even.
    """
    a = np.asarray(x_pk)
    return jnp.asarray(
        a[0:h:2, 0:w:2] | a[1:h:2, 0:w:2] | a[0:h:2, 1:w:2] | a[1:h:2, 1:w:2]
    )


# ---------------------------------------------------------------------------
# batch-norm / threshold fusion (§6.1)
# ---------------------------------------------------------------------------

def bn_ref(x, mean, var, gamma, beta, eps=1e-5):
    """Eq 4 batch normalization."""
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def bn_to_threshold(mean, var, gamma, beta, eps=1e-5):
    """Fold bn+sign into a threshold compare: sign(bn(x)) == +1 iff
    x >= tau when gamma > 0 (x <= tau when gamma < 0).

    Returns (tau, flip) where flip indicates the gamma<0 direction.
    """
    tau = mean - beta * jnp.sqrt(var + eps) / gamma
    flip = gamma < 0
    return tau, flip


def threshold_ref(x, tau, flip):
    """Apply the fused thrd op: +1 / -1 decision (float output)."""
    ge = jnp.where(x >= tau, 1.0, -1.0)
    return jnp.where(flip, -ge, ge).astype(jnp.float32)


def htanh_ref(x):
    """Eq 5 hard tanh."""
    return jnp.clip(x, -1.0, 1.0)
