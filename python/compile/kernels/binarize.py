"""Pallas binarize/pack kernel — the sign+pack front of every binarized layer.

The paper performs input binarization with warp-wide ``__ballot`` (§5.2);
on the Pallas side the ballot is a vectorized compare + shift-reduce over a
(rows, 32) VMEM block.  Fusing compare and pack keeps the +/-1 intermediate
out of HBM, which is the entire point of the 32x bandwidth claim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# rows per grid step; the minor axis is always a whole packed word group.
TR = 8


def _binarize_tile_kernel(x_ref, t_ref, o_ref):
    """(TR, n) float vs per-column threshold -> (TR, n/32) uint32."""
    x = x_ref[...]
    ge = (x >= t_ref[...][None, :]).astype(jnp.uint32)
    w = ge.reshape(x.shape[0], x.shape[1] // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    o_ref[...] = jnp.sum(w << shifts, axis=-1).astype(jnp.uint32)


def binarize_pack(x, thresh=None):
    """sign(x - thresh) packed along the last axis, LSB-first.

    x: (M, N) float32 with N % 32 == 0, M % TR == 0.
    thresh: optional (N,) float32 (defaults to 0 — plain Eq 1 sign).
    Returns (M, N/32) uint32.
    """
    m, n = x.shape
    assert n % 32 == 0 and m % TR == 0, (m, n)
    if thresh is None:
        thresh = jnp.zeros((n,), jnp.float32)
    grid = (m // TR,)
    return pl.pallas_call(
        _binarize_tile_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n // 32), jnp.uint32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TR, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TR, n // 32), lambda i: (i, 0)),
        interpret=True,
    )(x, thresh)
