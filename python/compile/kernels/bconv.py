"""Pallas bit-convolution (BConv) kernels — Layer 1.

Implements the paper's §5.3 scheme: with the input in HWNC and the filter
in KKCO layout, the contribution of one filter tap (r,s) at one output
point (p,q) is a bit matrix product (N, C) x (C, O) — Eq 3 — evaluated as
XOR+POPC (Eq 2).  Zero padding is handled exactly like Listing 6: taps
falling outside the frame are *excluded* (never read) and counted, and the
+/-1 amendment  out = C*(KK - exclude) - 2*acc  is applied at the end,
which resolves the "padded 0 is indistinguishable from -1" problem that
breaks im2col for BNNs.

Grid = output pixels; each grid step computes the full (N, O) tile for one
(p, q).  The whole packed input and filter are kept VMEM-resident: fine for
the interpret-mode correctness path used here (a real-TPU build would block
H/W with halos — see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bconv_kernel(inp_ref, fil_ref, o_ref, *, c, kh, kw, stride, pad, h, w):
    p = pl.program_id(0)
    q = pl.program_id(1)
    n = inp_ref.shape[2]
    o = fil_ref.shape[2]
    acc = jnp.zeros((n, o), jnp.int32)
    exclude = jnp.zeros((), jnp.int32)
    for r in range(kh):
        for s in range(kw):
            i = p * stride - pad + r
            j = q * stride - pad + s
            valid = (i >= 0) & (i < h) & (j >= 0) & (j < w)
            ic = jnp.clip(i, 0, h - 1)
            jc = jnp.clip(j, 0, w - 1)
            a = pl.load(inp_ref, (ic, jc, slice(None), slice(None)))
            b = pl.load(fil_ref, (r, s, slice(None), slice(None)))
            x = jnp.bitwise_xor(a[:, None, :], b[None, :, :])
            pc = jnp.sum(jnp.bitwise_count(x).astype(jnp.int32), axis=-1)
            acc = acc + jnp.where(valid, pc, 0)
            exclude = exclude + jnp.where(valid, 0, 1).astype(jnp.int32)
    n_valid = jnp.int32(c) * (jnp.int32(kh * kw) - exclude)
    o_ref[0, 0] = n_valid - 2 * acc


def bconv(inp_pk, fil_pk, c: int, stride: int = 1, pad: int = 1):
    """Packed BConv with exclude amendment.

    inp_pk: (H, W, N, C/32) uint32 (HWNC, packed along C)
    fil_pk: (K, K, O, C/32) uint32 (KKCO, packed along C, O-major)
    Returns (Ho, Wo, N, O) int32 — the +/-1 cross-correlation with
    zero padding treated as excluded taps.
    """
    h, w, n, cp = inp_pk.shape
    kh, kw, o, cp2 = fil_pk.shape
    assert cp == cp2 and cp * 32 == c
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    return pl.pallas_call(
        functools.partial(
            _bconv_kernel, c=c, kh=kh, kw=kw, stride=stride, pad=pad, h=h, w=w
        ),
        out_shape=jax.ShapeDtypeStruct((ho, wo, n, o), jnp.int32),
        grid=(ho, wo),
        in_specs=[
            pl.BlockSpec((h, w, n, cp), lambda p, q: (0, 0, 0, 0)),
            pl.BlockSpec((kh, kw, o, cp), lambda p, q: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, n, o), lambda p, q: (p, q, 0, 0)),
        interpret=True,
    )(inp_pk, fil_pk)


def _bconv_bin_kernel(
    inp_ref, fil_ref, t_ref, f_ref, o_ref, *, c, kh, kw, stride, pad, h, w
):
    p = pl.program_id(0)
    q = pl.program_id(1)
    n = inp_ref.shape[2]
    o = fil_ref.shape[2]
    acc = jnp.zeros((n, o), jnp.int32)
    exclude = jnp.zeros((), jnp.int32)
    for r in range(kh):
        for s in range(kw):
            i = p * stride - pad + r
            j = q * stride - pad + s
            valid = (i >= 0) & (i < h) & (j >= 0) & (j < w)
            ic = jnp.clip(i, 0, h - 1)
            jc = jnp.clip(j, 0, w - 1)
            a = pl.load(inp_ref, (ic, jc, slice(None), slice(None)))
            b = pl.load(fil_ref, (r, s, slice(None), slice(None)))
            x = jnp.bitwise_xor(a[:, None, :], b[None, :, :])
            pc = jnp.sum(jnp.bitwise_count(x).astype(jnp.int32), axis=-1)
            acc = acc + jnp.where(valid, pc, 0)
            exclude = exclude + jnp.where(valid, 0, 1).astype(jnp.int32)
    n_valid = jnp.int32(c) * (jnp.int32(kh * kw) - exclude)
    y = (n_valid - 2 * acc).astype(jnp.float32)  # (N, O)
    ge = y >= t_ref[...][None, :]
    bit = jnp.where(f_ref[...][None, :] != 0, ~ge, ge)
    wds = bit.astype(jnp.uint32).reshape(n, o // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    o_ref[0, 0] = jnp.sum(wds << shifts, axis=-1).astype(jnp.uint32)


def bconv_bin(inp_pk, fil_pk, c: int, thresh, flip, stride: int = 1, pad: int = 1):
    """Fused BConv -> thrd -> re-pack (packed in, packed out).

    thresh/flip: (O,) per-output-channel threshold parameters.
    Returns (Ho, Wo, N, O/32) uint32 — directly consumable as the next
    binarized layer's HWNC input.
    """
    h, w, n, cp = inp_pk.shape
    kh, kw, o, cp2 = fil_pk.shape
    assert cp == cp2 and cp * 32 == c and o % 32 == 0
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    return pl.pallas_call(
        functools.partial(
            _bconv_bin_kernel, c=c, kh=kh, kw=kw, stride=stride, pad=pad, h=h, w=w
        ),
        out_shape=jax.ShapeDtypeStruct((ho, wo, n, o // 32), jnp.uint32),
        grid=(ho, wo),
        in_specs=[
            pl.BlockSpec((h, w, n, cp), lambda p, q: (0, 0, 0, 0)),
            pl.BlockSpec((kh, kw, o, cp), lambda p, q: (0, 0, 0, 0)),
            pl.BlockSpec((o,), lambda p, q: (0,)),
            pl.BlockSpec((o,), lambda p, q: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, n, o // 32), lambda p, q: (p, q, 0, 0)),
        interpret=True,
    )(inp_pk, fil_pk, thresh, flip)


def maxpool2_or(x_pk):
    """2x2 stride-2 max pool over packed +/-1 bits == OR of 4 words (§6.1)."""
    h, w = x_pk.shape[0], x_pk.shape[1]
    return (
        x_pk[0:h:2, 0:w:2]
        | x_pk[1:h:2, 0:w:2]
        | x_pk[0:h:2, 1:w:2]
        | x_pk[1:h:2, 1:w:2]
    )
