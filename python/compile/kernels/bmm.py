"""Pallas bit-matrix-multiplication (BMM) kernels — Layer 1.

TPU re-think of the paper's BTC BMM (DESIGN.md §Hardware-Adaptation):

* operands are bit-packed uint32 exactly like the Turing BMMA operands
  (row-major packed A, column-major packed B == packed rows of B^T);
* the XOR+POPC dot product of Eq 2 runs on the vector unit
  (``jnp.bitwise_count``), not the MXU — bit compute is ALU work;
* the BlockSpec fixes the VMEM tile of A/B to a constant minor-dim
  stride regardless of the logical matrix width: the Pallas analogue of
  the FSB format's fixed ``ldm = 128``;
* ``bmm_bin`` fuses the downstream threshold + re-pack (the paper's
  Design-3 ``__ballot`` fusion) so the activation never materializes in
  int32 form in HBM.

All kernels use ``interpret=True``: the CPU PJRT runtime cannot execute
Mosaic custom-calls, and correctness on this rig is validated through the
interpret path (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. TM mirrors the BMMA row tile (8); TN is one packed output
# word-group (128 = 4 u32 words) so the fused binarized variant can re-pack
# in registers, exactly like the warp-wide __ballot of Listing 5.
TM = 8
TN = 128


def _bmm_tile_kernel(a_ref, b_ref, o_ref, *, k: int):
    """One (TM, TN) output tile: Eq 2 over packed uint32 operands."""
    a = a_ref[...]  # (TM, k/32) uint32
    b = b_ref[...]  # (TN, k/32) uint32
    x = jnp.bitwise_xor(a[:, None, :], b[None, :, :])
    p = jnp.sum(jnp.bitwise_count(x).astype(jnp.int32), axis=-1)
    o_ref[...] = jnp.int32(k) - 2 * p


def bmm(a_pk, b_pk, k: int):
    """Packed BMM: (M, k/32) x (N, k/32) -> (M, N) int32  (Eq 2).

    M must divide TM, N must divide TN.  The full packed-K extent is kept
    resident per tile (FC layers have k <= 4096 -> <= 512 B/row: trivially
    VMEM-resident; this is the "whole bit-row per tile" schedule of
    Design-2/3).
    """
    m, kp = a_pk.shape
    n, kp2 = b_pk.shape
    assert kp == kp2 and kp * 32 == k, (a_pk.shape, b_pk.shape, k)
    assert m % TM == 0 and n % TN == 0, (m, n)
    grid = (m // TM, n // TN)
    return pl.pallas_call(
        functools.partial(_bmm_tile_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TM, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((TN, kp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TM, TN), lambda i, j: (i, j)),
        interpret=True,
    )(a_pk, b_pk)


def _bmm_bin_tile_kernel(a_ref, b_ref, t_ref, f_ref, o_ref, *, k: int):
    """Fused tile: Eq 2 product -> thrd (bn+sign) -> re-pack to uint32.

    t_ref: (TN,) float32 thresholds; f_ref: (TN,) int32 flip flags
    (gamma < 0 inverts the compare direction, see ref.bn_to_threshold).
    """
    a = a_ref[...]
    b = b_ref[...]
    x = jnp.bitwise_xor(a[:, None, :], b[None, :, :])
    p = jnp.sum(jnp.bitwise_count(x).astype(jnp.int32), axis=-1)
    y = (jnp.int32(k) - 2 * p).astype(jnp.float32)  # (TM, TN)
    ge = y >= t_ref[...][None, :]
    bit = jnp.where(f_ref[...][None, :] != 0, ~ge, ge)  # +1 decision
    # register re-pack (the __ballot analogue): LSB-first within each word
    w = bit.astype(jnp.uint32).reshape(TM, TN // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    o_ref[...] = jnp.sum(w << shifts, axis=-1).astype(jnp.uint32)


def bmm_bin(a_pk, b_pk, k: int, thresh, flip):
    """BNN-specific BMM: packed in, packed out (Design-3 fusion).

    thresh: (N,) float32; flip: (N,) int32 (0/1).
    Returns (M, N/32) uint32.
    """
    m, kp = a_pk.shape
    n, kp2 = b_pk.shape
    assert kp == kp2 and kp * 32 == k
    assert m % TM == 0 and n % TN == 0
    grid = (m // TM, n // TN)
    return pl.pallas_call(
        functools.partial(_bmm_bin_tile_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((m, n // 32), jnp.uint32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TM, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((TN, kp), lambda i, j: (j, 0)),
            pl.BlockSpec((TN,), lambda i, j: (j,)),
            pl.BlockSpec((TN,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((TM, TN // 32), lambda i, j: (i, j)),
        interpret=True,
    )(a_pk, b_pk, thresh, flip)
