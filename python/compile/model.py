"""Layer-2 JAX model graphs for the BNN networks (build-time only).

The inference graphs here are the paper's Fig 15 pipeline after the §6.1
inference-time rewrites:

    thrd -> bconv/bmm -> thrd -> pool(OR) -> ... -> fc(int) -> bn -> logits

i.e. every hidden layer consumes and produces *packed bits* (uint32), all
bn+sign pairs are folded into per-neuron thresholds, pooling is a logical
OR, and only the first (binarize) and last (bn/logits) stages touch floats.
The hot ops are the Pallas kernels from `kernels/` so the whole network
lowers into a single HLO module per (model, batch) pair.

Weights enter as *arguments* (not constants): the rust runtime feeds them
from `artifacts/*.bin` once per process and reuses the buffers across
requests (donated on the request path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import binarize, bmm, bconv

# ---------------------------------------------------------------------------
# MNIST MLP (Table 5 row 1): 1024FC-1024FC-1024FC -> 10
# Input 28x28 = 784, zero-padded to 800 (25 packed words) so the packed
# inner dimension is word-aligned; the pad bits are part of the trained
# model (absorbed by the bn thresholds).
# ---------------------------------------------------------------------------

MLP_IN = 800          # 784 padded to a multiple of 32
MLP_HIDDEN = 1024
MLP_CLASSES = 10
MLP_OUT_PAD = 128     # final-layer neurons padded to one BMM tile column


def mlp_forward(x, in_thresh, w1, t1, f1, w2, t2, f2, w3, t3, f3, w4, g4, b4):
    """BNN-MLP inference graph.

    x:         (B, 800) float32 pixels (last 16 columns zero)
    in_thresh: (800,)   input binarization threshold
    w1:        (1024, 25) uint32  packed FC1 weight rows (column-major B)
    t1, f1:    (1024,) f32 / int32 fused bn thresholds for FC1
    w2, w3:    (1024, 32) uint32
    w4:        (128, 32)  uint32  output layer, rows 10..127 are padding
    g4, b4:    (128,) f32 final bn scale/shift
    Returns (B, 10) float32 logits.
    """
    xp = binarize.binarize_pack(x, in_thresh)                 # (B, 25)
    h1 = bmm.bmm_bin(xp, w1, MLP_IN, t1, f1)                  # (B, 32)
    h2 = bmm.bmm_bin(h1, w2, MLP_HIDDEN, t2, f2)              # (B, 32)
    h3 = bmm.bmm_bin(h2, w3, MLP_HIDDEN, t3, f3)              # (B, 32)
    v = bmm.bmm(h3, w4, MLP_HIDDEN).astype(jnp.float32)       # (B, 128)
    logits = v * g4[None, :] + b4[None, :]
    return logits[:, :MLP_CLASSES]


def mlp_arg_specs(batch):
    """ShapeDtypeStructs for jax.jit(...).lower — order matches mlp_forward."""
    f32, u32, i32 = jnp.float32, jnp.uint32, jnp.int32
    s = jax.ShapeDtypeStruct
    return [
        s((batch, MLP_IN), f32),
        s((MLP_IN,), f32),
        s((MLP_HIDDEN, MLP_IN // 32), u32),
        s((MLP_HIDDEN,), f32),
        s((MLP_HIDDEN,), i32),
        s((MLP_HIDDEN, MLP_HIDDEN // 32), u32),
        s((MLP_HIDDEN,), f32),
        s((MLP_HIDDEN,), i32),
        s((MLP_HIDDEN, MLP_HIDDEN // 32), u32),
        s((MLP_HIDDEN,), f32),
        s((MLP_HIDDEN,), i32),
        s((MLP_OUT_PAD, MLP_HIDDEN // 32), u32),
        s((MLP_OUT_PAD,), f32),
        s((MLP_OUT_PAD,), f32),
    ]


# ---------------------------------------------------------------------------
# A small binarized conv block (Cifar-lite): used as the standalone BConv
# artifact exercising the Layer-1 bconv kernel through the rust runtime.
# ---------------------------------------------------------------------------

def conv_block_forward(inp_pk, fil_pk, thresh, flip, c, stride=1, pad=1):
    """One fused binarized conv layer + 2x2 OR pooling.

    inp_pk: (H, W, N, C/32) uint32; fil_pk: (K, K, O, C/32) uint32.
    Returns (H/2, W/2, N, O/32) uint32.
    """
    y = bconv.bconv_bin(inp_pk, fil_pk, c, thresh, flip, stride, pad)
    return bconv.maxpool2_or(y)


def conv_block_arg_specs(h, w, n, c, o, k=3):
    s = jax.ShapeDtypeStruct
    return [
        s((h, w, n, c // 32), jnp.uint32),
        s((k, k, o, c // 32), jnp.uint32),
        s((o,), jnp.float32),
        s((o,), jnp.int32),
    ]


# ---------------------------------------------------------------------------
# Standalone BMM graph (runtime microbenchmark / kernel-as-a-service)
# ---------------------------------------------------------------------------

def bmm_forward(a_pk, b_pk, k):
    return bmm.bmm(a_pk, b_pk, k)


def bmm_arg_specs(m, n, k):
    s = jax.ShapeDtypeStruct
    return [s((m, k // 32), jnp.uint32), s((n, k // 32), jnp.uint32)]
