"""STE trainer for the MNIST-class BNN-MLP (build-time only).

Trains the Table-5 MLP (1024FC x3 -> 10) with the standard BNN recipe
(Courbariaux et al.: BinaryConnect weights + sign/htanh straight-through
activations + batch-norm), then folds bn+sign into per-neuron thresholds
and exports packed-bit weights for the rust runtime.

Dataset substitution (DESIGN.md §2): the environment is offline, so MNIST
is replaced by a procedural look-alike — 10 smoothed class templates with
per-sample noise and jitter, 28x28 grayscale in [0,1].  The task exercises
the identical code path; accuracy numbers are recorded against *this*
dataset in EXPERIMENTS.md (paper MNIST numbers are cited alongside).

Outputs (under artifacts/):
    mlp_weights.bin / mlp_weights.meta   packed weights + thresholds
    testset.bin / testset.meta           held-out images + labels (rust e2e)
    oracle_logits.bin                    python-side logits for batch 0
    train_log.txt                        loss curve + accuracy per epoch
"""

from __future__ import annotations

import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from . import model as M
from .kernels import ref

EPS = 1e-5


# ---------------------------------------------------------------------------
# synthetic MNIST
# ---------------------------------------------------------------------------

def _smooth(img, it=2):
    for _ in range(it):
        img = 0.25 * (
            np.roll(img, 1, 0) + np.roll(img, -1, 0)
            + np.roll(img, 1, 1) + np.roll(img, -1, 1)
        )
    return img


def make_dataset(n_per_class=1200, n_test_per_class=100, seed=7):
    """10-class synthetic digit-like dataset, 28x28 in [0,1]."""
    rng = np.random.default_rng(seed)
    templates = []
    for _ in range(10):
        t = _smooth(rng.standard_normal((28, 28)), it=3)
        t = (t - t.min()) / (t.max() - t.min() + 1e-9)
        templates.append(t)

    def sample(cls, n):
        t = templates[cls]
        imgs = np.empty((n, 28, 28), np.float32)
        for i in range(n):
            dx, dy = rng.integers(-2, 3, size=2)
            s = np.roll(np.roll(t, dx, 0), dy, 1)
            s = 0.75 * s + 0.35 * rng.standard_normal((28, 28))
            imgs[i] = np.clip(s, 0.0, 1.0)
        return imgs

    def build(npc):
        xs, ys = [], []
        for c in range(10):
            xs.append(sample(c, npc))
            ys.append(np.full(npc, c, np.int32))
        x = np.concatenate(xs).reshape(-1, 784)
        y = np.concatenate(ys)
        p = rng.permutation(len(y))
        return x[p], y[p]

    xtr, ytr = build(n_per_class)
    xte, yte = build(n_test_per_class)
    return xtr, ytr, xte, yte


def pad800(x):
    """784 -> 800 with zero pad (packed-word alignment, see model.MLP_IN)."""
    return np.pad(x, ((0, 0), (0, M.MLP_IN - x.shape[1])))


# ---------------------------------------------------------------------------
# STE primitives
# ---------------------------------------------------------------------------

def ste_weight(w):
    """BinaryConnect: forward sign(w), backward identity."""
    s = jnp.where(w >= 0, 1.0, -1.0)
    return w + jax.lax.stop_gradient(s - w)


def ste_act(x):
    """Forward sign(x); backward htanh' = 1_{|x|<=1} (Fig 15 tanh->sign)."""
    h = jnp.clip(x, -1.0, 1.0)
    s = jnp.where(x >= 0, 1.0, -1.0)
    return h + jax.lax.stop_gradient(s - h)


def bn_train(v, gamma, beta):
    mu = jnp.mean(v, axis=0)
    var = jnp.var(v, axis=0)
    y = (v - mu) / jnp.sqrt(var + EPS) * gamma + beta
    return y, mu, var


# ---------------------------------------------------------------------------
# training-time forward (float, mirrors mlp_forward exactly)
# ---------------------------------------------------------------------------

def init_params(seed=0):
    rng = np.random.default_rng(seed)

    def glorot(shape):
        lim = np.sqrt(6.0 / (shape[0] + shape[1]))
        return jnp.asarray(rng.uniform(-lim, lim, shape), jnp.float32)

    p = {}
    dims = [(M.MLP_IN, M.MLP_HIDDEN), (M.MLP_HIDDEN, M.MLP_HIDDEN),
            (M.MLP_HIDDEN, M.MLP_HIDDEN), (M.MLP_HIDDEN, M.MLP_OUT_PAD)]
    for i, d in enumerate(dims, 1):
        p[f"w{i}"] = glorot(d)
        p[f"g{i}"] = jnp.ones((d[1],), jnp.float32)
        p[f"b{i}"] = jnp.zeros((d[1],), jnp.float32)
    return p


def forward_train(p, x):
    """Returns (logits, aux batch stats). x: (B, 800) in [0,1]."""
    a = jnp.where(x >= 0.5, 1.0, -1.0)
    stats = {}
    for i in (1, 2, 3):
        v = a @ ste_weight(p[f"w{i}"])
        y, mu, var = bn_train(v, p[f"g{i}"], p[f"b{i}"])
        stats[i] = (mu, var)
        a = ste_act(y)
    v = a @ ste_weight(p["w4"])
    y, mu, var = bn_train(v, p["g4"], p["b4"])
    stats[4] = (mu, var)
    return y[:, : M.MLP_CLASSES], stats


def loss_fn(p, x, labels):
    logits, stats = forward_train(p, x)
    lse = jax.nn.logsumexp(logits, axis=1)
    ll = logits[jnp.arange(labels.shape[0]), labels]
    return jnp.mean(lse - ll), stats


# ---------------------------------------------------------------------------
# hand-rolled Adam (no optax in this environment)
# ---------------------------------------------------------------------------

def adam_init(p):
    z = lambda t: jax.tree_util.tree_map(jnp.zeros_like, t)
    return {"m": z(p), "v": z(p), "t": 0}


def adam_step(p, grads, st, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    st = {"m": st["m"], "v": st["v"], "t": st["t"] + 1}
    t = st["t"]
    upd = {}
    for k in p:
        m = b1 * st["m"][k] + (1 - b1) * grads[k]
        v = b2 * st["v"][k] + (1 - b2) * grads[k] ** 2
        st["m"][k] = m
        st["v"][k] = v
        mh = m / (1 - b1**t)
        vh = v / (1 - b2**t)
        w = p[k] - lr * mh / (jnp.sqrt(vh) + eps)
        if k.startswith("w"):
            w = jnp.clip(w, -1.0, 1.0)  # BinaryConnect weight clipping
        upd[k] = w
    return upd, st


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

DTYPE_TAG = {np.float32: "f32", np.uint32: "u32", np.int32: "i32"}


def write_blob(path_base, tensors):
    """tensors: list of (name, np.ndarray). Writes .bin + .meta."""
    off = 0
    with open(path_base + ".bin", "wb") as fb, open(path_base + ".meta", "w") as fm:
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            tag = DTYPE_TAG[arr.dtype.type]
            shape = "x".join(str(d) for d in arr.shape)
            fm.write(f"{name} {tag} {shape} {off} {arr.nbytes}\n")
            fb.write(arr.tobytes())
            off += arr.nbytes
    return off


def fold_thresholds(w, gamma, beta, mu, var):
    """bn+sign -> (tau, flip) with safe handling of tiny gamma."""
    g = np.where(np.abs(gamma) < 1e-12, 1e-12 * np.sign(gamma + 1e-30), gamma)
    tau = mu - beta * np.sqrt(var + EPS) / g
    flip = (g < 0).astype(np.int32)
    return tau.astype(np.float32), flip


def export(p, running, out_dir):
    """Pack weights, fold bn, write the runtime blob."""
    tensors = [("in_thresh", np.full((M.MLP_IN,), 0.5, np.float32))]
    for i in (1, 2, 3):
        w = np.asarray(p[f"w{i}"])
        mu, var = running[i]
        tau, flip = fold_thresholds(
            w, np.asarray(p[f"g{i}"]), np.asarray(p[f"b{i}"]), mu, var
        )
        wpk = np.asarray(ref.pack_bits(w.T))  # (out, in/32) packed rows of W^T
        tensors += [(f"w{i}", wpk), (f"t{i}", tau), (f"f{i}", flip)]
    w4 = np.asarray(p["w4"])
    mu4, var4 = running[4]
    g4 = np.asarray(p["g4"]) / np.sqrt(var4 + EPS)
    b4 = np.asarray(p["b4"]) - mu4 * g4
    g4[M.MLP_CLASSES:] = 0.0
    b4[M.MLP_CLASSES:] = 0.0
    tensors += [
        ("w4", np.asarray(ref.pack_bits(w4.T))),
        ("g4", g4.astype(np.float32)),
        ("b4", b4.astype(np.float32)),
    ]
    return write_blob(os.path.join(out_dir, "mlp_weights"), tensors)


def load_weight_args(out_dir):
    """Reload the exported blob as the mlp_forward argument list (no x)."""
    metas = {}
    with open(os.path.join(out_dir, "mlp_weights.meta")) as f:
        for line in f:
            name, tag, shape, off, nbytes = line.split()
            metas[name] = (tag, shape, int(off), int(nbytes))
    blob = open(os.path.join(out_dir, "mlp_weights.bin"), "rb").read()
    npdt = {"f32": np.float32, "u32": np.uint32, "i32": np.int32}

    def get(name):
        tag, shape, off, nbytes = metas[name]
        dims = [int(d) for d in shape.split("x")]
        return np.frombuffer(blob[off : off + nbytes], npdt[tag]).reshape(dims)

    order = ["in_thresh", "w1", "t1", "f1", "w2", "t2", "f2",
             "w3", "t3", "f3", "w4", "g4", "b4"]
    return [jnp.asarray(get(n)) for n in order]


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def accuracy(p, running, x, y, batch=512):
    """Eval with running bn stats (the deployed model semantics)."""
    correct = 0
    for i in range(0, len(y), batch):
        xb = jnp.asarray(x[i : i + batch])
        a = jnp.where(xb >= 0.5, 1.0, -1.0)
        for l in (1, 2, 3):
            v = a @ jnp.where(p[f"w{l}"] >= 0, 1.0, -1.0)
            mu, var = running[l]
            yb = (v - mu) / jnp.sqrt(var + EPS) * p[f"g{l}"] + p[f"b{l}"]
            a = jnp.where(yb >= 0, 1.0, -1.0)
        v = a @ jnp.where(p["w4"] >= 0, 1.0, -1.0)
        mu, var = running[4]
        logits = ((v - mu) / jnp.sqrt(var + EPS) * p["g4"] + p["b4"])[
            :, : M.MLP_CLASSES
        ]
        correct += int(jnp.sum(jnp.argmax(logits, 1) == jnp.asarray(y[i : i + batch])))
    return correct / len(y)


def train(out_dir, epochs=6, batch=128, lr=2e-3, seed=0, log=print):
    xtr, ytr, xte, yte = make_dataset()
    xtr, xte = pad800(xtr), pad800(xte)
    p = init_params(seed)
    opt = adam_init(p)
    running = {i: (np.zeros(d, np.float32), np.ones(d, np.float32))
               for i, d in ((1, M.MLP_HIDDEN), (2, M.MLP_HIDDEN),
                            (3, M.MLP_HIDDEN), (4, M.MLP_OUT_PAD))}
    mom = 0.9

    @jax.jit
    def step(p, opt, xb, yb):
        (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, xb, yb)
        p, opt = adam_step(p, grads, opt, lr=lr)
        return p, opt, loss, stats

    lines = []
    nstep = 0
    t0 = time.time()
    for ep in range(epochs):
        perm = np.random.default_rng(seed + ep).permutation(len(ytr))
        ep_loss = 0.0
        nb = 0
        for i in range(0, len(ytr) - batch + 1, batch):
            idx = perm[i : i + batch]
            p, opt, loss, stats = step(p, opt, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]))
            for l, (mu, var) in stats.items():
                rm, rv = running[l]
                running[l] = (
                    mom * rm + (1 - mom) * np.asarray(mu),
                    mom * rv + (1 - mom) * np.asarray(var),
                )
            ep_loss += float(loss)
            nb += 1
            nstep += 1
            if nstep % 20 == 0:
                lines.append(f"step {nstep} loss {float(loss):.4f}")
        acc = accuracy(p, running, xte, yte)
        msg = (f"epoch {ep+1}/{epochs} avg_loss {ep_loss/nb:.4f} "
               f"test_acc {acc:.4f} elapsed {time.time()-t0:.1f}s")
        lines.append(msg)
        log(msg)

    acc = accuracy(p, running, xte, yte)
    os.makedirs(out_dir, exist_ok=True)
    export(p, running, out_dir)

    # held-out set + oracle logits for the rust e2e driver
    n_keep = 1024
    write_blob(
        os.path.join(out_dir, "testset"),
        [("images", xte[:n_keep].astype(np.float32)),
         ("labels", yte[:n_keep].astype(np.int32))],
    )
    args = load_weight_args(out_dir)
    logits0 = np.asarray(M.mlp_forward(jnp.asarray(xte[:8]), *args))
    write_blob(os.path.join(out_dir, "oracle_logits"), [("logits", logits0)])

    # deployed (threshold-folded, packed) accuracy on the held-out set
    correct = 0
    for i in range(0, n_keep, 128):
        lg = np.asarray(M.mlp_forward(jnp.asarray(xte[i : i + 128]), *args))
        correct += int((lg.argmax(1) == yte[i : i + 128]).sum())
    dep_acc = correct / n_keep
    lines.append(f"final float_bn_acc {acc:.4f} deployed_packed_acc {dep_acc:.4f}")
    log(lines[-1])
    with open(os.path.join(out_dir, "train_log.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    return acc, dep_acc


if __name__ == "__main__":
    import sys

    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    train(out)
