"""AOT compiler: lower the L2 graphs to HLO *text* artifacts + manifest.

Run once at build time (`make artifacts`); the rust runtime then loads the
text with `HloModuleProto::from_text_file` and never touches python again.

HLO text — NOT `lowered.compile()` / proto `.serialize()` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the published
`xla` 0.1.6 crate) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts produced:
    mlp_b{8,32,128}.hlo.txt     full MLP inference graph per batch bucket
    bmm_{n}.hlo.txt             standalone packed BMM (runtime microbench)
    conv_block.hlo.txt          fused bconv_bin + OR-pool block
    manifest.txt                artifact -> args/outs spec for the runtime
    mlp_weights.bin/.meta &c.   from train.py (trained on first build)
"""

from __future__ import annotations

import argparse
import os

import numpy as np

import jax
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T

MLP_BATCHES = (8, 32, 128)
BMM_SIZES = (1024,)
CONV_SPEC = dict(h=16, w=16, n=8, c=128, o=128, k=3)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_str(s) -> str:
    tag = {"float32": "f32", "uint32": "u32", "int32": "i32"}[str(s.dtype)]
    return f"{tag} {'x'.join(str(d) for d in s.shape)}"


def lower_artifact(name, fn, specs, out_dir, manifest, static=None):
    """Lower fn(*specs) to HLO text and append a manifest entry."""
    path = f"{name}.hlo.txt"
    lowered = jax.jit(fn, static_argnums=static or ()).lower(*specs)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)
    out_specs = jax.eval_shape(fn, *specs)
    if not isinstance(out_specs, (list, tuple)):
        out_specs = [out_specs]
    manifest.append(f"artifact {name} {path}")
    for i, s in enumerate(specs):
        manifest.append(f"arg a{i} {spec_str(s)}")
    for s in out_specs:
        manifest.append(f"out {spec_str(s)}")
    manifest.append("end")
    print(f"  lowered {name}: {len(text)} chars")


def build(out_dir, quick=False, skip_train=False):
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    # --- train the MLP (or reuse existing weights) --------------------------
    wpath = os.path.join(out_dir, "mlp_weights.bin")
    if skip_train and os.path.exists(wpath):
        print("  reusing existing mlp_weights.bin")
    else:
        print("  training MLP BNN (synthetic MNIST, STE)...")
        T.train(out_dir, epochs=2 if quick else 6)

    # --- full MLP graphs per batch bucket -----------------------------------
    for b in MLP_BATCHES:
        lower_artifact(
            f"mlp_b{b}", M.mlp_forward, M.mlp_arg_specs(b), out_dir, manifest
        )

    # --- standalone packed BMM ----------------------------------------------
    for n in BMM_SIZES:
        fn = lambda a, b, _n=n: M.bmm_forward(a, b, _n)
        lower_artifact(
            f"bmm_{n}", fn, M.bmm_arg_specs(n, n, n), out_dir, manifest
        )

    # --- fused conv block ----------------------------------------------------
    cs = CONV_SPEC
    fn = lambda i, f, t, fl: M.conv_block_forward(i, f, t, fl, cs["c"])
    lower_artifact(
        "conv_block",
        fn,
        M.conv_block_arg_specs(cs["h"], cs["w"], cs["n"], cs["c"], cs["o"], cs["k"]),
        out_dir,
        manifest,
    )

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"  wrote manifest ({len(manifest)} lines)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="fast dev build")
    ap.add_argument("--skip-train", action="store_true",
                    help="reuse mlp_weights.bin if present")
    args = ap.parse_args()
    build(args.out, quick=args.quick, skip_train=args.skip_train)


if __name__ == "__main__":
    main()
