//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real crate links the XLA runtime, which is unavailable in this
//! offline build environment.  This stub keeps the `runtime::executor`
//! module compiling: every entry point that would touch PJRT returns a
//! descriptive error at *runtime*, and all code paths that need it are
//! already gated behind artifact-presence checks (tests skip when
//! `artifacts/manifest.txt` is absent).  Host-side `Literal` containers
//! are implemented for real so data-marshalling code can be exercised.

use std::borrow::Borrow;
use std::fmt;

/// Error type matching the shape of the real bindings' error.
#[derive(Clone, Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: PJRT runtime unavailable in this offline build \
         (the `xla` crate is stubbed; see vendor/xla)"
    )))
}

/// Element types used by the tcbnn artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U32,
    S32,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        4
    }
}

/// Conversion from little-endian bytes for the supported host types.
pub trait NativeType: Sized + Copy {
    const TYPE: ElementType;
    fn from_le(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TYPE: ElementType = ElementType::F32;
    fn from_le(b: [u8; 4]) -> f32 {
        f32::from_le_bytes(b)
    }
}

impl NativeType for u32 {
    const TYPE: ElementType = ElementType::U32;
    fn from_le(b: [u8; 4]) -> u32 {
        u32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TYPE: ElementType = ElementType::S32;
    fn from_le(b: [u8; 4]) -> i32 {
        i32::from_le_bytes(b)
    }
}

/// A host-side literal: dtype + shape + raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    pub ty: ElementType,
    pub dims: Vec<usize>,
    pub bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal, XlaError> {
        let want = dims.iter().product::<usize>() * ty.byte_size();
        if want != data.len() {
            return Err(XlaError(format!(
                "literal shape {dims:?} needs {want} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        if T::TYPE != self.ty {
            return Err(XlaError(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TYPE
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Unpack a tuple literal.  The stub never produces tuples (nothing
    /// executes), so this only ever reports the runtime's absence.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module handle (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation handle (opaque in the stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (opaque in the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (opaque in the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.  `cpu()` fails in the stub, so nothing
/// downstream of it can ever be reached.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let xs: Vec<f32> = vec![1.0, -2.5, 3.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes)
                .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        assert!(lit.to_vec::<u32>().is_err());
    }

    #[test]
    fn runtime_paths_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[4],
            &[0u8; 8]
        )
        .is_err());
    }
}
