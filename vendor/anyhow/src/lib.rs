//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This vendored crate exists because the build environment has no
//! network access to crates.io.  It implements exactly the API subset
//! the tcbnn crate uses: `Error`, `Result`, the `anyhow!`, `bail!` and
//! `ensure!` macros, and the `Context` extension trait for `Result` and
//! `Option`.  Error values carry a context chain; `{}` prints the
//! outermost message and `{:#}` prints the whole chain, matching the
//! real crate's formatting behaviour closely enough for log output.

use std::fmt;

/// An error value: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }

    /// The root cause's message (innermost error).
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(s) = cur.source.as_deref() {
            cur = s;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full context chain, outermost first
            let mut first = true;
            for m in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for (i, m) in self.chain().skip(1).enumerate() {
                write!(f, "\n    {i}: {m}")?;
            }
        }
        Ok(())
    }
}

// Every std error converts into `Error` (this is what makes `?` work in
// functions returning `anyhow::Result`).  `Error` itself converts via
// the reflexive `From<T> for T`; the two impls never overlap because
// `Error` deliberately does not implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // preserve the std source chain as context messages
        let mut msgs = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(err) = cur {
            msgs.push(err.to_string());
            cur = err.source();
        }
        let mut out: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            out = Some(match out {
                None => Error::msg(m),
                Some(inner) => inner.context(m),
            });
        }
        out.unwrap_or_else(|| Error::msg("unknown error"))
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Attach a context message, converting the error into `Error`.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Lazily attach a context message.
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err()).context("reading foo").unwrap_err();
        assert_eq!(format!("{e}"), "reading foo");
        assert_eq!(format!("{e:#}"), "reading foo: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Result<i32> = None.context("missing");
        assert_eq!(format!("{}", v.unwrap_err()), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).is_err());
        assert_eq!(format!("{}", f(200).unwrap_err()), "too big: 200");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
